"""Benchmark harness — one function per paper table + kernel micro-bench +
calibration gates. Prints ``name,us_per_call,derived`` CSV rows and writes a
machine-readable ``BENCH_kernels.json`` (name → us_per_call + derived) so
the perf trajectory is tracked PR-over-PR. Conv-kernel + ResNet9
end-to-end rows are additionally dumped to ``BENCH_conv.json``; the graph-
compiler rows (compiled vs hand-written packed path, executor dispatch
overhead) to ``BENCH_compile.json``; the serving-runtime rows (bucketed
steady-state vs re-jit-per-shape, latency percentiles, precision mix) to
``BENCH_serving.json``; the bank-scaling rows (1 vs 4 MVU banks, virtual
+ wall domains, sharded/pipelined placements) to
``BENCH_distributed.json``; the AOT artifact-store rows (cold compile vs
warm boot of a 2-model x 2-precision registry) to ``BENCH_coldstart.json``;
the continuous-batching LM rows (static chunked vs token-granular decode
on a heterogeneous stream) to ``BENCH_lm.json``; the observability
overhead rows (serving smoke with tracing off vs on, metric write cost
enabled vs disabled) to ``BENCH_obs.json``; the measured-profiler /
cost-model calibration rows (fitted ns-per-virtual-cycle, max relative
residual, measured tile re-rank never-slower gate, profiler off-path
zero-overhead gate) to ``BENCH_calibration.json``. After a run,
``python -m benchmarks.history`` appends the gated scalars to
``BENCH_history.jsonl`` and ``python -m benchmarks.regress`` gates the
newest record against the rolling baseline.

Run: PYTHONPATH=src python -m benchmarks.run
     [--only kernels,tables,conv,compile,serving,distributed,coldstart,
      lm,obs,calibration]
     [--json BENCH_kernels.json] [--conv-json BENCH_conv.json]
     [--compile-json BENCH_compile.json]
     [--serving-json BENCH_serving.json]
     [--distributed-json BENCH_distributed.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time
import timeit

import numpy as np

_ROWS: dict = {}
# per-group artifact keys: group tag -> row names (dumped to the group's
# own BENCH_*.json next to the all-rows dump)
_GROUP_KEYS: dict = {"conv": [], "compile": [], "serving": [],
                     "distributed": [], "coldstart": [], "lm": [],
                     "obs": [], "calibration": []}


def _emit(name: str, us: float, derived: str = "",
          group: str = None) -> None:
    """One result row: CSV to stdout + recorded for the JSON dump(s).
    ``group`` additionally tags the row for that group's artifact."""
    print(f"{name},{us:.0f},{derived}")
    _ROWS[name] = {"us_per_call": round(float(us), 1), "derived": derived}
    if group is not None:
        _GROUP_KEYS[group].append(name)


def _time_us(fn, n=5, warmup=1, repeat=3):
    """Best-of-``repeat`` mean over ``n`` calls — the minimum strips
    scheduler/contention spikes, which otherwise dominate on shared CI
    machines and make speedup ratios unstable."""
    for _ in range(warmup):
        fn()
    return min(timeit.repeat(fn, number=n, repeat=repeat)) / n * 1e6


def _time_interleaved_us(fns, n=2, rounds=4):
    """Time several candidates under the SAME load: alternate them
    round-robin and take each one's best round. Timing A fully then B fully
    lets a background-load shift land entirely on one side and corrupt the
    A/B ratio; interleaving makes both sides sample every load phase."""
    for fn in fns:
        fn()  # warmup/compile
    best = [float("inf")] * len(fns)
    for _ in range(rounds):
        for i, fn in enumerate(fns):
            best[i] = min(best[i], timeit.timeit(fn, number=n) / n * 1e6)
    return best


def table2_model_sizes():
    """Paper Table 2: ResNet9 model sizes (fp32 vs int2 packed)."""
    import jax
    from repro.core.codegen import export_weights
    from repro.models.resnet import ResNet9Config, resnet9_init
    cfg = ResNet9Config()
    t0 = time.time()
    params = resnet9_init(jax.random.PRNGKey(0), cfg)
    conv = {n: params[n]["w"] for n, *_ in cfg.layers}
    exported = export_weights(conv, w_bits=2)
    packed = sum(v.packed.nbytes for v in exported.values())
    fp32 = sum(params[n]["w"].nbytes for n, *_ in cfg.layers)
    us = (time.time() - t0) * 1e6
    # paper: Plain-CNN fp32 18912487 B, Int2 1181360 B
    _emit("table2_fp32_bytes", us, f"{fp32} (paper 18912487)")
    _emit("table2_int2_bytes", us, f"{packed} (paper 1181360)")
    _emit("table2_compression", us, f"{fp32/packed:.1f}x")


def table3_resnet9_cycles():
    """Paper Table 3: per-layer ResNet9 cycles at W2/A2."""
    import repro.core.cost_model as cm
    t0 = time.time()
    cyc = cm.network_cycles(cm.RESNET9_CIFAR10, 2, 2, edge="paper_edge")
    named = {l.name: c for l, c in zip(cm.RESNET9_CIFAR10, cyc)}
    us = (time.time() - t0) * 1e6
    exact = 0
    for k, v in cm.RESNET9_PAPER_CYCLES.items():
        match = named[k] == v
        exact += match
        _emit(f"table3_{k}", us,
              f"{named[k]} (paper {v} {'EXACT' if match else 'dev'})")
    total = sum(cyc)
    _emit("table3_total", us,
          f"{total} (paper {cm.RESNET9_PAPER_TOTAL} "
          f"{'EXACT' if total == cm.RESNET9_PAPER_TOTAL else ''}) "
          f"[{exact}/8 layers exact]")
    # the other edge variants, for the reconciliation note
    for edge in ("dense", "pad_skip"):
        t = sum(cm.network_cycles(cm.RESNET9_CIFAR10, 2, 2, edge=edge))
        _emit(f"table3_total_{edge}", us, str(t))


def table5_cnv_fps():
    """Paper Table 5: CNV throughput vs precision (scaling law)."""
    import repro.core.cost_model as cm
    t0 = time.time()
    us = (time.time() - t0) * 1e6
    for (w, a), paper in cm.CNV_PAPER_FPS.items():
        fps = cm.pipelined_fps(cm.CNV_CIFAR10, a, w)
        _emit(f"table5_cnv_W{w}A{a}", us,
              f"{fps:.0f} FPS (paper {paper}; ratio {fps/paper:.2f})")
    f11 = cm.pipelined_fps(cm.CNV_CIFAR10, 1, 1)
    f22 = cm.pipelined_fps(cm.CNV_CIFAR10, 2, 2)
    _emit("table5_scaling_1x1_over_2x2", us, f"{f11/f22:.2f} (paper 4.00)")


def table6_resnet50():
    """Paper Table 6: ResNet-50 FPS and FPS/W."""
    import repro.core.cost_model as cm
    t0 = time.time()
    layers = cm.resnet50_layers()
    fps_d = cm.distributed_fps(layers, 2, 1, edge="paper_edge")
    fps_p = cm.pipelined_fps(layers, 2, 1, edge="paper_edge")
    us = (time.time() - t0) * 1e6
    hw = cm.HWConfig()
    _emit("table6_resnet50_fps", us,
          f"{fps_d:.0f} (paper {cm.RESNET50_PAPER['fps']}; "
          "distributed-mode estimate)")
    _emit("table6_resnet50_fps_per_watt", us,
          f"{fps_d/hw.power_w:.1f} "
          f"(paper {cm.RESNET50_PAPER['fps_per_watt']}; FILM-QNN 8.4)")
    _emit("table6_resnet50_fps_pipelined", us, f"{fps_p:.0f}")


def bench_serial_matmul():
    """Micro-bench: XLA serve path, seed digit plan (radix 7, two plane
    products at W4A8) vs the v2 plan-selected path (radix 8, one).

    CPU timings are indicative only; the TPU target uses the Pallas kernel.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import bitops
    from repro.core.bitserial import (SerialSpec, plan_spec,
                                      serial_matmul_packed)
    rng = np.random.RandomState(0)
    m, k, n = 256, 1024, 1024
    x = jnp.asarray(rng.randint(-128, 128, (m, k)), jnp.int32)
    w = rng.randint(-8, 8, (k, n)).astype(np.int32)
    planes = bitops.pad_to(bitops.to_bitplanes(jnp.asarray(w), 4), 32, axis=1)
    wp = bitops.pack_bitplanes(planes, axis=1)
    xf = jnp.asarray(rng.randn(m, k), jnp.float32)
    wf = jnp.asarray(rng.randn(k, n), jnp.float32)

    f_float = jax.jit(lambda a, b: a @ b)
    shape_tag = f"{m}x{k}x{n}"
    cases = [
        ("seed", SerialSpec(8, 4, True, True, 7)),           # seed default
        ("v2", plan_spec(SerialSpec(8, 4, True, True, 7))),  # tuned plan
        ("bitserial_r2", SerialSpec(8, 4, True, True, 1)),   # faithful
    ]
    fns = []
    for _, spec in cases:
        f = jax.jit(lambda xx, ww, s=spec: serial_matmul_packed(
            xx, ww, spec=s, k=k))
        fns.append(lambda f=f: jax.block_until_ready(f(x, wp)))
    times = _time_interleaved_us(fns, n=2, rounds=6)
    results = {}
    for (name, spec), us in zip(cases, times):
        results[name] = us
        _emit(f"bench_serial_matmul_W4A8_{name}_{shape_tag}", us,
              f"{spec.num_plane_products} plane products "
              f"(radix {spec.radix_bits})")
    _emit("bench_serial_matmul_W4A8_v2_speedup", 0,
          f"{results['seed']/results['v2']:.2f}x vs seed")
    us_f = _time_us(lambda: jax.block_until_ready(f_float(xf, wf)))
    _emit(f"bench_float_matmul_{shape_tag}", us_f, "fp32 reference")


def bench_pallas_kernel():
    """Pallas kernels in interpret mode, W4A8, same logical shape: seed v1
    (int-code acts, per-step plane unpack) vs v2 (packed acts, hoisted
    VMEM-scratch digit planes, tuned digit plan)."""
    import jax
    import jax.numpy as jnp
    from repro.core import bitops
    from repro.core.bitserial import SerialSpec, plan_spec
    from repro.kernels.bitserial_matmul import (bitserial_matmul_pallas,
                                                bitserial_matmul_v2_pallas)
    rng = np.random.RandomState(0)
    m, k, n = 128, 512, 128
    bm, bn, bk = 16, 32, 128       # multi-block grid on every axis
    x = rng.randint(-128, 128, (m, k)).astype(np.int32)
    w = rng.randint(-8, 8, (k, n)).astype(np.int32)
    wp = bitops.pack_bitplanes(
        bitops.pad_to(bitops.to_bitplanes(jnp.asarray(w), 4), 32, axis=1),
        axis=1)
    xp = bitops.pack_bitplanes(
        bitops.pad_to(bitops.to_bitplanes(jnp.asarray(x), 8), 32, axis=-1),
        axis=-1)
    scale = np.ones(n, np.float32)
    shape_tag = f"{m}x{k}x{n}"

    from repro.core.quant import QuantSpec
    seed_spec = SerialSpec(8, 4, True, True, 7)
    v2_spec = plan_spec(seed_spec)
    fn_v1 = jax.jit(lambda xx, ww: bitserial_matmul_pallas(
        jnp.asarray(xx), ww, scale, None, spec=seed_spec, k=k, block_m=bm,
        block_n=bn, block_k=bk, interpret=True))
    fn_v2 = jax.jit(lambda xx, ww: bitserial_matmul_v2_pallas(
        xx, ww, scale, None, spec=v2_spec, k=k, block_m=bm, block_n=bn,
        block_k=bk, interpret=True))
    # fused requant->bit-transpose-pack epilogue (layer-chaining output)
    fn_v2p = jax.jit(lambda xx, ww: bitserial_matmul_v2_pallas(
        xx, ww, scale, None, spec=v2_spec, k=k, requant=QuantSpec(8, True),
        requant_scale=jnp.asarray(0.5), emit_packed=True, block_m=bm,
        block_n=bn, block_k=bk, interpret=True))
    us_v1, us_v2, us_v2p = _time_interleaved_us([
        lambda: jax.block_until_ready(fn_v1(x, wp)),
        lambda: jax.block_until_ready(fn_v2(xp, wp)),
        lambda: jax.block_until_ready(fn_v2p(xp, wp)),
    ], n=2, rounds=4)
    _emit(f"bench_pallas_kernel_W4A8_seed_{shape_tag}", us_v1,
          f"v1, blocks ({bm},{bn},{bk}), interpret")
    _emit(f"bench_pallas_kernel_W4A8_v2_{shape_tag}", us_v2,
          "v2, packed acts + hoisted planes, interpret")
    _emit("bench_pallas_kernel_W4A8_v2_speedup", 0,
          f"{us_v1/us_v2:.2f}x vs seed")
    _emit(f"bench_pallas_kernel_W4A8_v2_fusedpack_{shape_tag}", us_v2p,
          "v2 + fused requant-pack epilogue, interpret")


def bench_tuner():
    """Autotuner overhead: cold enumeration vs in-process cache hit."""
    from repro.core.bitserial import SerialSpec
    from repro.kernels import tuning
    spec = SerialSpec(8, 4, True, True, 8)
    tuning.clear_cache()
    t0 = time.time()
    tc = tuning.choose_tile(64, 4096, 4096, spec)
    cold = (time.time() - t0) * 1e6
    us_hit = _time_us(lambda: tuning.choose_tile(64, 4096, 4096, spec),
                      n=100, warmup=1)
    _emit("bench_tuner_cold_us", cold,
          f"blocks ({tc.block_m},{tc.block_n},{tc.block_k}) "
          f"cw={tc.cache_weights} ca={tc.cache_acts} "
          f"vmem={tc.vmem_bytes/2**20:.2f}MiB")
    _emit("bench_tuner_cache_hit_us", us_hit,
          f"{tuning.cache_info()['entries']} entries")


def _resnet9_conv_shapes():
    """(name, c_in, c_out, input H=W, stride) of every hidden conv, derived
    from the ResNet9Config the model actually runs (3x3 pad-1 convs, 2x2
    pools after the flagged stages) so benchmark and model cannot drift."""
    from repro.models.resnet import ResNet9Config
    cfg = ResNet9Config()
    shapes, h = [], 32
    for (name, ci, co, stride, pool) in cfg.layers:
        shapes.append((name, ci, co, h, stride))
        h = (h - 1) // stride + 1
        if pool:
            h //= 2
    return shapes


def bench_conv_layers():
    """ResNet9 W2A2 conv layers: the seed path (f32 im2col round-trip +
    v1 serial GEMM) vs the implicit-GEMM packed path (tap-walk dataflow of
    the conv kernel, XLA lowering — CPU timings indicative; the TPU target
    runs kernels/bitserial_conv.py)."""
    import jax
    import jax.numpy as jnp
    from repro.core import bitops
    from repro.core.bitserial import SerialSpec, plan_spec, serial_matmul
    from repro.kernels.ops import pack_activations, serial_conv2d_packed_op
    spec = plan_spec(SerialSpec(2, 2, True, True, 7))
    rng = np.random.RandomState(0)

    def seed_conv(x, w, stride):
        # the seed serial_conv2d: f32 patch extraction (a ~9x blown patch
        # tensor through HBM) -> cast back -> one big serial GEMM
        patches = jax.lax.conv_general_dilated_patches(
            x.astype(jnp.float32), (3, 3), (stride, stride),
            [(1, 1), (1, 1)],
            dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(jnp.int32)
        wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(-1, w.shape[-1])
        return serial_matmul(patches, wmat, spec)

    tot_seed = tot_imp = 0.0
    for (name, ci, co, hw, stride) in _resnet9_conv_shapes():
        x = jnp.asarray(rng.randint(-2, 2, (8, hw, hw, ci)), jnp.int32)
        w = jnp.asarray(rng.randint(-2, 2, (3, 3, ci, co)), jnp.int32)
        xp = pack_activations(x, 2)
        wp = bitops.pack_bitplanes(
            bitops.pad_to(bitops.to_bitplanes(w, 2), 32, axis=3), axis=3)
        scale = jnp.ones(co, jnp.float32)
        f_seed = jax.jit(lambda a, b, s=stride: seed_conv(a, b, s))
        f_imp = jax.jit(lambda a, b, s=stride, c=ci: serial_conv2d_packed_op(
            a, b, scale, None, spec=spec, ci=c, stride=s, padding=1,
            backend="xla"))
        us_seed, us_imp = _time_interleaved_us([
            lambda: jax.block_until_ready(f_seed(x, w)),
            lambda: jax.block_until_ready(f_imp(xp, wp)),
        ], n=1, rounds=3)
        tot_seed += us_seed
        tot_imp += us_imp
        _emit(f"bench_conv_W2A2_{name}_seed_im2col", us_seed,
              f"8x{hw}x{hw}x{ci}->{co} s{stride}", group="conv")
        _emit(f"bench_conv_W2A2_{name}_implicit", us_imp,
              f"{us_seed / us_imp:.2f}x vs seed", group="conv")
    _emit("bench_conv_W2A2_resnet9_stack_speedup", 0,
          f"{tot_seed / tot_imp:.2f}x vs im2col+v1 serial GEMM "
          f"(stack {tot_seed:.0f}us -> {tot_imp:.0f}us)", group="conv")


def bench_conv_pallas_kernel():
    """Pallas kernels in interpret mode, one W2A2 conv stage: seed recipe
    (host int im2col + v1 serial matmul kernel) vs the implicit-GEMM conv
    kernel (patch generation inside the kernel, digit-plane caches)."""
    import jax
    import jax.numpy as jnp
    from repro.core import bitops
    from repro.core.bitserial import SerialSpec, plan_spec
    from repro.kernels.bitserial_matmul import bitserial_matmul_pallas
    from repro.kernels.bitserial_conv import bitserial_conv2d_v2_pallas
    spec = plan_spec(SerialSpec(2, 2, True, True, 7))
    rng = np.random.RandomState(0)
    n, hw, ci, co, stride = 2, 8, 64, 64, 1
    x = jnp.asarray(rng.randint(-2, 2, (n, hw, hw, ci)), jnp.int32)
    w = jnp.asarray(rng.randint(-2, 2, (3, 3, ci, co)), jnp.int32)
    scale = np.ones(co, np.float32)
    from repro.kernels.ops import pack_activations
    xp = pack_activations(x, 2)
    wp_conv = bitops.pack_bitplanes(
        bitops.pad_to(bitops.to_bitplanes(w, 2), 32, axis=3), axis=3)
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(-1, co)
    wp_mat = bitops.pack_bitplanes(
        bitops.pad_to(bitops.to_bitplanes(wmat, 2), 32, axis=1), axis=1)
    k = 9 * ci

    def seed_kernel(xx):
        patches = jax.lax.conv_general_dilated_patches(
            xx.astype(jnp.float32), (3, 3), (stride, stride),
            [(1, 1), (1, 1)],
            dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(jnp.int32)
        return bitserial_matmul_pallas(
            patches.reshape(-1, k), wp_mat, scale, None, spec=spec, k=k,
            block_m=32, block_n=32, block_k=192, interpret=True)

    f_v1 = jax.jit(seed_kernel)
    f_v2 = jax.jit(lambda a: bitserial_conv2d_v2_pallas(
        a, wp_conv, scale, None, spec=spec, ci=ci, stride=stride,
        padding=1, block_co=32, block_nb=2, interpret=True))
    us_v1, us_v2 = _time_interleaved_us([
        lambda: jax.block_until_ready(f_v1(x)),
        lambda: jax.block_until_ready(f_v2(xp)),
    ], n=1, rounds=3)
    tag = f"{n}x{hw}x{hw}x{ci}->{co}"
    _emit(f"bench_conv_pallas_W2A2_seed_{tag}", us_v1,
          "im2col + v1 matmul kernel, interpret", group="conv")
    _emit(f"bench_conv_pallas_W2A2_v2_{tag}", us_v2,
          f"implicit-GEMM conv kernel, interpret; "
          f"{us_v1 / us_v2:.2f}x vs seed", group="conv")


def bench_resnet9_e2e():
    """ResNet9/CIFAR10 end-to-end (paper Tables 2/3 workload, batch 4):
    seed quantized forward (per-call weight re-quantization + f32 im2col)
    vs the hoisted forward vs the packed deployment path (implicit-GEMM,
    stages chained in packed format)."""
    import jax
    import jax.numpy as jnp
    from repro.models.resnet import (ResNet9Config, resnet9_init,
                                     resnet9_forward, resnet9_pack,
                                     resnet9_forward_packed,
                                     resnet9_quantize_weights)
    cfg = ResNet9Config()
    params = resnet9_init(jax.random.PRNGKey(0), cfg)
    images = jnp.asarray(np.random.RandomState(0).rand(4, 32, 32, 3),
                         jnp.float32)
    t0 = time.time()
    qw = resnet9_quantize_weights(params, cfg)
    qw = jax.tree_util.tree_map(jax.block_until_ready, qw)
    us_quant = (time.time() - t0) * 1e6
    t0 = time.time()
    packed = resnet9_pack(params, images, cfg)
    packed = jax.tree_util.tree_map(jax.block_until_ready, packed)
    us_pack = (time.time() - t0) * 1e6
    f_seed = jax.jit(lambda p, im: resnet9_forward(p, im, cfg))
    f_hoist = jax.jit(lambda p, im, q: resnet9_forward(p, im, cfg,
                                                       qweights=q))
    f_packed = jax.jit(lambda p, im: resnet9_forward_packed(
        p, im, cfg, backend="xla"))
    us_seed, us_hoist, us_packed = _time_interleaved_us([
        lambda: jax.block_until_ready(f_seed(params, images)),
        lambda: jax.block_until_ready(f_hoist(params, images, qw)),
        lambda: jax.block_until_ready(f_packed(packed, images)),
    ], n=1, rounds=3)
    _emit("bench_resnet9_W2A2_seed_forward", us_seed,
          "per-call weight quant + f32 im2col, batch 4", group="conv")
    _emit("bench_resnet9_W2A2_hoisted_forward", us_hoist,
          f"one-time weight quant ({us_quant:.0f}us); "
          f"{us_seed / us_hoist:.2f}x vs seed", group="conv")
    _emit("bench_resnet9_W2A2_packed_forward", us_packed,
          f"implicit-GEMM packed chain (pack {us_pack:.0f}us one-time); "
          f"{us_seed / us_packed:.2f}x vs seed", group="conv")


def bench_compile_resnet9():
    """Graph-compiler ResNet9 vs the hand-written packed path: same calib
    batch, same XLA packed-kernel lowering — the compiled Program must sit
    within 5% of `resnet9_forward_packed` (acceptance: the compiler
    generalizes the PR1/PR2 wins without taxing the hand-tuned path)."""
    import jax
    import jax.numpy as jnp
    from repro.core.codegen import generate
    from repro.models.resnet import (ResNet9Config, resnet9_compile,
                                     resnet9_cost_layers,
                                     resnet9_forward_packed, resnet9_init,
                                     resnet9_pack)
    cfg = ResNet9Config()
    params = resnet9_init(jax.random.PRNGKey(0), cfg)
    images = jnp.asarray(np.random.RandomState(0).rand(4, 32, 32, 3),
                         jnp.float32)
    t0 = time.time()
    prog = resnet9_compile(params, images, cfg, backend="xla")
    prog(images).block_until_ready()  # include first jit in compile cost
    us_compile = (time.time() - t0) * 1e6
    packed = resnet9_pack(params, images, cfg)
    f_hand = jax.jit(lambda p, im: resnet9_forward_packed(
        p, im, cfg, backend="xla"))
    # deterministic same-computation evidence first: XLA cost analysis of
    # both jitted programs (CPU wall-clock on shared CI is noisy)
    def _cost(f, *a):
        c = f.lower(*a).compile().cost_analysis()
        c = c[0] if isinstance(c, list) else c
        return (c or {}).get("flops"), (c or {}).get("bytes accessed")
    from repro.compiler import executor as _pex
    f_comp = jax.jit(_pex.make_runner(prog))
    cost_hand = _cost(f_hand, packed, images)
    cost_comp = _cost(f_comp, prog.params, images)
    us_hand, us_comp = _time_interleaved_us([
        lambda: jax.block_until_ready(f_hand(packed, images)),
        lambda: jax.block_until_ready(prog(images)),
    ], n=2, rounds=8)
    exact = bool(jnp.all(prog(images) == f_hand(packed, images)))
    ratio = us_comp / us_hand
    _emit("bench_compile_resnet9_hand_packed", us_hand,
          "resnet9_forward_packed, XLA, batch 4", group="compile")
    _emit("bench_compile_resnet9_compiled", us_comp,
          f"graph-compiler Program; {ratio:.3f}x hand time "
          f"(within 5%: {ratio <= 1.05}); bit_exact={exact}", group="compile")
    _emit("bench_compile_resnet9_hlo_cost", 0,
          f"flops/bytes hand={cost_hand} compiled={cost_comp} "
          f"(identical: {cost_hand == cost_comp})", group="compile")
    _emit("bench_compile_resnet9_compile_time", us_compile,
          "one-time: passes+calibration+packing+tuning+first jit", group="compile")
    hand_cs = generate(resnet9_cost_layers(cfg), a_bits=cfg.a_bits,
                       w_bits=cfg.w_bits)
    comp_cs = prog.to_command_stream()
    _emit("bench_compile_resnet9_cycles", 0,
          f"per-MVU {comp_cs.per_mvu_cycles} "
          f"(matches hand codegen: "
          f"{comp_cs.per_mvu_cycles == hand_cs.per_mvu_cycles})", group="compile")


def bench_compile_dispatch():
    """Executor dispatch overhead: a trivial one-gemm Program, jitted call
    (the serving path — whole step list fused into one XLA computation)
    vs eager step-walk (`Program.run`)."""
    import jax
    import jax.numpy as jnp
    from repro.compiler import Graph, Node, compile_graph
    from repro.models.layers import QuantPolicy
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.rand(8, 256), jnp.float32)
    g = Graph("one_gemm", {"x": (None, 256)}, ["y"],
              [Node("fc", "gemm", ["x", "w"], "fy"),
               Node("r", "relu", ["fy"], "y")],
              {"w": (rng.randn(256, 256) * 0.1).astype(np.float32)})
    prog = compile_graph(g, x, policy=QuantPolicy(
        mode="serial", w_bits=4, a_bits=8, radix_bits=7), backend="xla")
    prog(x)  # compile
    us_jit = _time_us(lambda: jax.block_until_ready(prog(x)), n=20)
    us_eager = _time_us(lambda: jax.block_until_ready(prog.run(x)), n=5)
    _emit("bench_compile_dispatch_jit", us_jit,
          "jitted Program call (serving path)", group="compile")
    _emit("bench_compile_dispatch_eager", us_eager,
          f"eager step walk; jit removes {us_eager - us_jit:.0f}us/call "
          "of dispatch", group="compile")


def bench_quantized_lm_serve():
    """Tokens/s of the smoke LM through the full quantized serve path."""
    from repro.configs import get_arch
    from repro.launch.serve import GenRequest, Server
    cfg = get_arch("stablelm-1.6b").smoke
    server = Server(cfg, batch_slots=2, max_len=48)
    rng = np.random.RandomState(0)
    reqs = [GenRequest(rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32),
                       8) for _ in range(2)]
    server.generate(reqs)  # warmup/compile
    t0 = time.perf_counter()
    out = server.generate(reqs)
    dt = time.perf_counter() - t0
    ntok = sum(len(r.out_tokens) for r in out)
    _emit("bench_lm_serve_W4A8", dt / max(ntok, 1) * 1e6,
          f"{ntok/dt:.1f} tok/s (smoke cfg, CPU)")


def _serving_bench_graph(name="serving_cnn", seed=0):
    """Small two-serial-layer CNN: cheap to compile at several precisions,
    still exercises the packed conv + gemm serving kernels."""
    from repro.compiler import Graph, Node
    rng = np.random.RandomState(seed)
    g = Graph(
        name, {"x": (None, 8, 8, 8)}, ["y"],
        [Node("c1", "conv2d", ["x", "c1.w"], "c1.y",
              {"stride": 1, "padding": 1}),
         Node("c1.relu", "relu", ["c1.y"], "c1.r"),
         Node("gap", "global_avg_pool", ["c1.r"], "pooled"),
         Node("fc", "gemm", ["pooled", "fc.w"], "y")],
        {"c1.w": (rng.randn(3, 3, 8, 16) * 0.2).astype(np.float32),
         "fc.w": (rng.randn(16, 10) * 0.2).astype(np.float32)})
    calib = rng.rand(4, 8, 8, 8).astype(np.float32)
    return g, calib


def bench_serving():
    """Multi-tenant serving runtime vs the seed behavior it replaces.

    Workload: a mixed stream — the same CNN at W2A2 and W4A8, client
    batches of every size 1..12 (each precision sees every size once).
    Baseline = the pre-serving ``CNNServer.classify`` discipline: direct
    jitted Program calls, so every previously-unseen (precision, batch
    shape) pays a trace+compile in-window. Bucketed = the serving runtime
    post-warmup: per-example submit through the dynamic batcher, padded to
    power-of-two buckets, jit-cache closed over {variant} x {bucket} —
    steady state never recompiles (asserted from the cache counters).
    """
    import jax
    import jax.numpy as jnp
    from repro.models.layers import QuantPolicy
    from repro.serving import InferenceService, ModelRegistry
    g, calib = _serving_bench_graph()
    reg = ModelRegistry(backend="xla")
    k_lo = reg.register_graph("cnn", g, calib, QuantPolicy(
        mode="serial", w_bits=2, a_bits=2, radix_bits=7))
    k_hi = reg.register_graph("cnn", g, calib, QuantPolicy(
        mode="serial", w_bits=4, a_bits=8, radix_bits=7))
    rng = np.random.RandomState(1)
    sizes = list(range(1, 13))
    client = [((k_lo, k_hi)[i % 2], rng.rand(s, 8, 8, 8).astype(np.float32))
              for i, s in enumerate(sizes + sizes)]
    nreq = sum(x.shape[0] for _, x in client)

    # ---- baseline: re-jit per shape (the seed CNNServer.classify path)
    progs = {k: reg.program(k) for k in (k_lo, k_hi)}
    for p in progs.values():
        p._jit_cache.clear()              # a fresh server facing the stream
    t0 = time.perf_counter()
    for k, x in client:
        jax.block_until_ready(progs[k](jnp.asarray(x)))
    dt_base = time.perf_counter() - t0
    _emit("bench_serving_rejit_baseline", dt_base / nreq * 1e6,
          f"{nreq/dt_base:.1f} req/s over {nreq} reqs; "
          f"{len(sizes)} shapes x 2 precisions each trace+compile",
          group="serving")

    # ---- serving runtime: same stream, per-example submit, buckets
    svc = InferenceService(reg, max_batch=16, max_wait_s=0.001)
    with svc:
        n_warm = svc.warmup()
        warm = {k: v["compiles"]
                for k, v in svc.metrics()["bucket_caches"].items()}
        t0 = time.perf_counter()
        futs = []
        for k, x in client:
            futs += svc.submit_many(k, list(x))
        svc.drain()
        dt_svc = time.perf_counter() - t0
        for f in futs:
            f.result()
        m = svc.metrics()
    recompiles = sum(v["compiles"] - warm[k]
                     for k, v in m["bucket_caches"].items())
    _emit("bench_serving_bucketed", dt_svc / nreq * 1e6,
          f"{nreq/dt_svc:.1f} req/s steady-state; "
          f"p50 {m['latency_p50_ms']:.1f}ms p99 {m['latency_p99_ms']:.1f}ms; "
          f"recompiles_after_warmup={recompiles} "
          f"({n_warm} bucket compiles at warmup)", group="serving")
    _emit("bench_serving_speedup", 0,
          f"{dt_base/dt_svc:.2f}x vs re-jit-per-shape baseline "
          f"(>=2x required)", group="serving")
    sched = m["scheduler"]
    _emit("bench_serving_precision_mix", 0,
          f"W2A2+W4A8 co-scheduled on {len(sched['slot_utilization'])} "
          f"virtual MVU slots; mean busy-slot utilization "
          f"{sched['mean_busy_utilization']:.3f}; "
          f"{sched['admitted_batches']} batches "
          f"{sched['admitted_requests']} reqs "
          f"{sched['virtual_cycles']} virtual cycles", group="serving")
    _emit("bench_serving_queue", 0,
          f"peak depth {m['peak_queue_depth']}; "
          f"straggler events {m['straggler']['events']}", group="serving")


def bench_obs():
    """Observability overhead gate (``BENCH_obs.json``, CI-gated).

    The serving smoke A/B'd under the same load: one service with the
    tracer disabled (the null TraceContext fast path), one with it
    enabled at ``sample_every=1`` so every request records
    queue/schedule/execute/finalize spans plus per-hart cycle tracks.
    Rounds are interleaved (best-of) so a background-load shift cannot
    land on one side and fake a regression. Enabled tracing must stay
    within 5% of disabled throughput; a disabled registry's counter
    write must cost ~one flag check (≈0 at machine scale).
    """
    from repro.compiler import Graph, Node
    from repro.models.layers import QuantPolicy
    from repro.obs import MetricsRegistry, Tracer
    from repro.serving import InferenceService, ModelRegistry

    # heavier than _serving_bench_graph on purpose: the relative gate is
    # meaningless on a microsecond-scale toy (a fixed ~8us/req emit cost
    # would dominate any ratio); this two-conv CNN puts per-request time
    # at realistic serving scale while the absolute cost is still emitted
    rng = np.random.RandomState(5)
    g = Graph(
        "obs_cnn", {"x": (None, 8, 8, 16)}, ["y"],
        [Node("c1", "conv2d", ["x", "c1.w"], "c1.y",
              {"stride": 1, "padding": 1}),
         Node("c1.relu", "relu", ["c1.y"], "c1.r"),
         Node("c2", "conv2d", ["c1.r", "c2.w"], "c2.y",
              {"stride": 1, "padding": 1}),
         Node("c2.relu", "relu", ["c2.y"], "c2.r"),
         Node("gap", "global_avg_pool", ["c2.r"], "pooled"),
         Node("fc", "gemm", ["pooled", "fc.w"], "y")],
        {"c1.w": (rng.randn(3, 3, 16, 32) * 0.2).astype(np.float32),
         "c2.w": (rng.randn(3, 3, 32, 32) * 0.2).astype(np.float32),
         "fc.w": (rng.randn(32, 10) * 0.2).astype(np.float32)})
    calib = rng.rand(4, 8, 8, 16).astype(np.float32)
    reg = ModelRegistry(backend="xla")
    key = reg.register_graph("obs_cnn", g, calib, QuantPolicy(
        mode="serial", w_bits=2, a_bits=2, radix_bits=7))
    payloads = [rng.rand(8, 8, 16).astype(np.float32) for _ in range(96)]
    n = len(payloads)

    def pass_once(svc):
        futs = svc.submit_many(key, payloads)
        svc.drain()
        for f in futs:
            f.result()

    best = {False: float("inf"), True: float("inf")}
    svc_off = InferenceService(reg, max_batch=16, max_wait_s=0.001,
                               tracer=Tracer(enabled=False))
    svc_on = InferenceService(reg, max_batch=16, max_wait_s=0.001,
                              tracer=Tracer(enabled=True))
    with svc_off, svc_on:
        for svc in (svc_off, svc_on):
            svc.warmup()
            pass_once(svc)          # close every jit cache pre-timing
        for _ in range(4):          # interleaved A/B rounds, best-of
            for enabled, svc in ((False, svc_off), (True, svc_on)):
                t0 = time.perf_counter()
                pass_once(svc)
                best[enabled] = min(best[enabled],
                                    time.perf_counter() - t0)
        tstats = svc_on.tracer.stats()
        off_buffered = svc_off.tracer.stats()["buffered"]
    dis, en = best[False], best[True]
    overhead = (en - dis) / dis * 100.0
    _emit("bench_obs_tracing_disabled", dis / n * 1e6,
          f"{n/dis:.1f} req/s, tracer off "
          f"({off_buffered} spans buffered)", group="obs")
    _emit("bench_obs_tracing_enabled", en / n * 1e6,
          f"{n/en:.1f} req/s, tracer on sample_every=1 "
          f"({tstats['buffered']} spans, {tstats['sampled']} requests "
          "sampled)", group="obs")
    # us_per_call carries the clamped percentage so CI can gate on the
    # numeric field; derived keeps the signed value for the report.
    _emit("bench_obs_tracing_overhead_pct", max(overhead, 0.0),
          f"{overhead:+.2f}% enabled vs disabled (<=5% gated); "
          f"absolute {(en - dis)/n*1e6:+.1f}us/req", group="obs")

    # ---- metric write path: a disabled registry must cost ~nothing
    on = MetricsRegistry().counter("bench_writes_total")
    off = MetricsRegistry(enabled=False).counter("bench_writes_total")
    writes = 50_000

    def spin(c):
        for _ in range(writes):
            c.inc()

    ns_on = _time_us(lambda: spin(on), n=1, warmup=1, repeat=5) \
        / writes * 1e3
    ns_off = _time_us(lambda: spin(off), n=1, warmup=1, repeat=5) \
        / writes * 1e3
    # _ns rows: us_per_call holds nanoseconds (a sub-0.1us value would
    # round to zero in the JSON dump and be ungateable)
    _emit("bench_obs_counter_inc_enabled_ns", ns_on,
          f"{ns_on:.0f} ns/inc, labelled counter write", group="obs")
    _emit("bench_obs_counter_inc_disabled_ns", ns_off,
          f"{ns_off:.0f} ns/inc — one enabled-flag check "
          f"({ns_on/max(ns_off, 1e-9):.1f}x cheaper than enabled)",
          group="obs")

    # ---- static verification (REPRO_VERIFY): the off path must invoke
    # the verifier exactly zero times, and the on path must stay within
    # 10% of compile_graph wall time. Interleaving is pointless here (the
    # flag flips a whole phase), so each side takes best-of-3 on a fresh
    # gemm graph; the tile-tuner cache is warm for both after round one.
    from repro import analysis
    from repro.compiler.lower import compile_graph

    def _verify_workload():
        rng2 = np.random.RandomState(7)
        gg = Graph(
            "verify_gemm", {"x": (None, 16)}, ["y"],
            [Node("fc", "gemm", ["x", "fc.w"], "y")],
            {"fc.w": (rng2.randn(16, 8) * 0.2).astype(np.float32)})
        return gg, rng2.rand(4, 16).astype(np.float32)

    def _compile_best_of(rounds=3):
        best_s = float("inf")
        for _ in range(rounds):
            gg, cal = _verify_workload()
            t0 = time.perf_counter()
            prog = compile_graph(gg, cal)
            prog.to_command_stream()
            best_s = min(best_s, time.perf_counter() - t0)
        return best_s

    saved_flag = os.environ.pop("REPRO_VERIFY", None)
    try:
        analysis.reset_counters()
        t_off = _compile_best_of()
        gated_calls = sum(analysis.counters()[s]
                          for s in analysis.GATED_SITES)
        if gated_calls:
            raise AssertionError(
                f"verification ran {gated_calls} time(s) with "
                "REPRO_VERIFY unset — the disabled path must be free")
        os.environ["REPRO_VERIFY"] = "1"
        analysis.reset_counters()
        t_on = _compile_best_of()
        on_calls = sum(analysis.counters()[s]
                       for s in analysis.GATED_SITES)
    finally:
        if saved_flag is None:
            os.environ.pop("REPRO_VERIFY", None)
        else:
            os.environ["REPRO_VERIFY"] = saved_flag
    verify_pct = (t_on - t_off) / t_off * 100.0
    _emit("bench_obs_verify_off_path", t_off * 1e6,
          f"verifier_calls=0 across 3 compile+stream rounds with "
          "REPRO_VERIFY unset (counter-proven)", group="obs")
    _emit("bench_obs_verify_compile_overhead_pct", max(verify_pct, 0.0),
          f"{verify_pct:+.2f}% compile_graph wall with verification on "
          f"({on_calls} verifier calls; <=10% gated)", group="obs")


def bench_lm():
    """Continuous-batching LM decode vs the static chunked baseline.

    Workload: a heterogeneous stream of 16 greedy requests (prompts 4-16
    tokens; every 4th request wants a long completion, the rest short) on
    the stablelm smoke config. Static = ``Server.generate`` in arrival-
    order chunks of ``batch_slots``: each chunk decodes
    ``max(max_new_tokens)`` steps, so one straggler pins three finished
    slots. Continuous = ``ContinuousLMEngine.serve``: requests join/leave
    the slot arena at token boundaries, a freed slot admits the next
    prompt on the very next step. Both paths run post-warmup (closed jit
    caches); the continuous row asserts zero steady-state recompiles and
    every request is checked bit-exact against a single-request static
    decode before the rows are emitted.
    """
    from repro.configs.base import get_arch
    from repro.launch.serve import GenRequest, Server
    from repro.serving import ContinuousLMEngine

    cfg = get_arch("stablelm-1.6b").smoke
    slots, max_len = 4, 64
    rng = np.random.RandomState(7)
    reqs = []
    for i in range(16):
        L = int(rng.randint(4, 17))
        if i % 4 == 0:                      # 1-in-4 long completions
            M = int(min(40 + rng.randint(0, 9), max_len - L))
        else:
            M = int(rng.randint(4, 9))
        reqs.append((rng.randint(0, cfg.vocab_size, (L,)).astype(np.int32),
                     M))
    n_tok = sum(m for _, m in reqs)

    # ---- static baseline: chunked Server.generate, post-warmup
    server = Server(cfg, batch_slots=slots, max_len=max_len, seed=0)
    chunks = [reqs[i:i + slots] for i in range(0, len(reqs), slots)]
    for c in chunks:                        # warm the per-shape jit cache
        server.generate([GenRequest(p.copy(), m) for p, m in c])
    lat_static, t0 = [], time.perf_counter()
    for c in chunks:
        server.generate([GenRequest(p.copy(), m) for p, m in c])
        done = time.perf_counter() - t0     # whole chunk finishes together
        lat_static += [done * 1e3] * len(c)
    dt_static = time.perf_counter() - t0
    steps_static = sum(max(m for _, m in c) for c in chunks)
    _emit("bench_lm_static", dt_static / n_tok * 1e6,
          f"{n_tok/dt_static:.1f} tok/s over {len(reqs)} reqs "
          f"({n_tok} tokens, {steps_static} chunk-steps); "
          f"p50 {np.percentile(lat_static, 50):.1f}ms "
          f"p99 {np.percentile(lat_static, 99):.1f}ms; "
          f"chunks of {slots} decode max(max_new) steps", group="lm")

    # ---- continuous engine: same stream through the slot arena
    engine = ContinuousLMEngine(cfg, batch_slots=slots, max_len=max_len,
                                seed=0)
    engine.warmup()
    t0 = time.perf_counter()
    out = engine.serve([GenRequest(p.copy(), m) for p, m in reqs])
    dt_cont = time.perf_counter() - t0
    em = engine.engine_metrics()
    recompiles = engine.stats()["recompiles_after_warmup"]
    assert recompiles == 0, f"steady-state recompiles: {engine.stats()}"
    _emit("bench_lm_continuous", dt_cont / n_tok * 1e6,
          f"{n_tok/dt_cont:.1f} tok/s ({em['decode_steps']} decode steps); "
          f"p50 {em['latency_p50_ms']:.1f}ms "
          f"p99 {em['latency_p99_ms']:.1f}ms; "
          f"slot_occupancy={em['slot_occupancy']:.2f}; "
          f"recompiles_after_warmup={recompiles}", group="lm")
    _emit("bench_lm_speedup", 0,
          f"{dt_static/dt_cont:.2f}x tokens/s vs static chunked baseline "
          f"(>=2x required)", group="lm")

    # ---- greedy outputs must be bit-exact per request vs a
    # single-request static decode (no co-resident may perturb anyone)
    exact = all(
        r.out_tokens == server.generate(
            [GenRequest(p.copy(), m)])[0].out_tokens
        for r, (p, m) in zip(out, reqs))
    assert exact, "continuous decode diverged from single-request static"
    _emit("bench_lm_bit_exact", 0,
          f"bit_exact={exact} over {len(reqs)} requests vs "
          f"single-request static decode", group="lm")


def bench_coldstart():
    """AOT artifact store: cold compile vs warm boot of a 2-model x
    2-precision registry.

    Cold = a fresh registry materializing all 4 variants through
    ``compile_graph`` (passes + calibration + packing + autotuning),
    persisting each to an artifact store. Warm = a restarted process (fresh
    registry, fresh graph objects, empty tuner L1) pointed at the same
    store: ``warm_boot()`` must restore every variant with **zero**
    compiles and zero autotuner enumerations, serve bit-exact, and be
    >=5x faster than the cold path (the CI gate)."""
    import shutil
    import tempfile
    from repro.kernels import tuning
    from repro.models.layers import QuantPolicy
    from repro.serving import ModelRegistry

    pols = [QuantPolicy(mode="serial", w_bits=2, a_bits=2, radix_bits=7),
            QuantPolicy(mode="serial", w_bits=4, a_bits=8, radix_bits=7)]

    def register_all(reg):
        # fresh graph objects each time — compiling annotates a graph in
        # place, and a restarted process never sees the annotated one
        keys = []
        for name, seed in (("cold_a", 0), ("cold_b", 7)):
            g, calib = _serving_bench_graph(name, seed)
            keys += [reg.register_graph(name, g, calib, p) for p in pols]
        return keys

    root = tempfile.mkdtemp(prefix="coldstart_store_")
    x = np.random.RandomState(3).rand(2, 8, 8, 8).astype(np.float32)
    try:
        tuning.clear_cache()
        reg = ModelRegistry(store=root)
        keys = register_all(reg)
        t0 = time.perf_counter()
        outs = {str(k): np.asarray(reg.program(k)(x)) for k in keys}
        dt_cold = time.perf_counter() - t0
        _emit("bench_coldstart_cold_compile", dt_cold * 1e6,
              f"{len(keys)} variants (2 models x 2 precisions); "
              f"compiles={reg.compiles}", group="coldstart")

        tuning.clear_cache()                 # a restart has an empty L1
        reg2 = ModelRegistry(store=root)
        keys2 = register_all(reg2)
        t0 = time.perf_counter()
        report = reg2.warm_boot()
        dt_warm = time.perf_counter() - t0
        enums = tuning.cache_info()["enumerations"]
        exact = all(np.array_equal(outs[str(k)],
                                   np.asarray(reg2.program(k)(x)))
                    for k in keys2)
        _emit("bench_coldstart_warm_boot", dt_warm * 1e6,
              f"restored={len(report['restored'])} "
              f"recompiles_after_warm_boot={reg2.compiles} "
              f"autotuner_enumerations={enums} bit_exact={exact}",
              group="coldstart")
        _emit("bench_coldstart_speedup", 0,
              f"{dt_cold/dt_warm:.1f}x warm boot vs cold compile "
              f"(>=5x required)", group="coldstart")
        st = reg2.store.stats()
        _emit("bench_coldstart_store", 0,
              f"programs={st['programs']} blobs={st['blobs']} "
              f"bytes_on_disk={st['bytes_on_disk']} "
              f"dedup_ratio={st['dedup_ratio']} "
              f"load_p50_ms={st['load_p50_ms']}", group="coldstart")
    finally:
        tuning.set_persistent_store(None)
        tuning.clear_cache()
        shutil.rmtree(root, ignore_errors=True)


def bench_distributed():
    """Mesh-of-MVU-banks scaling: the mixed W2A2+W4A8 serving stream at 1
    vs 4 banks (one 8-slot bank per device).

    Runs :mod:`benchmarks.distributed` in a subprocess so the worker can
    force ``--xla_force_host_platform_device_count=8`` before jax
    initializes. Scaling is reported in two domains: **virtual** (the
    barrel-controller cycle clock the paper tables model — the >=2x CI
    gate) and **wall** (this host; fake devices share the physical cores,
    so wall scaling is informational).
    """
    import json as _json
    import subprocess
    import sys
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)      # worker sets its own device count
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    try:
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.distributed"],
            capture_output=True, text=True, env=env, timeout=1200)
    except subprocess.TimeoutExpired:
        _emit("bench_distributed_error", 0, "worker timed out (1200s)",
              group="distributed")
        return
    if out.returncode != 0:
        _emit("bench_distributed_error", 0,
              f"worker failed: {out.stderr[-300:]}", group="distributed")
        return
    try:
        r = _json.loads(out.stdout.strip().splitlines()[-1])
    except (IndexError, ValueError):
        _emit("bench_distributed_error", 0,
              f"unparseable worker output: {out.stdout[-200:]!r}",
              group="distributed")
        return
    if "error" in r:
        _emit("bench_distributed_error", 0, r["error"], group="distributed")
        return
    w1, w4 = r["wall"]["1"], r["wall"]["4"]
    v1, v4 = r["virtual"]["1"], r["virtual"]["4"]
    vscale = v1["virtual_seconds"] / v4["virtual_seconds"]
    wscale = w4["req_s"] / w1["req_s"]
    _emit("bench_distributed_banks1", 1e6 / w1["req_s"],
          f"{w1['req_s']:.1f} req/s wall; "
          f"{v1['req_per_vsec']:.0f} req/vsec virtual (8 slots); "
          f"recompiles_after_warmup={w1['recompiles']}", group="distributed")
    _emit("bench_distributed_banks4", 1e6 / w4["req_s"],
          f"{w4['req_s']:.1f} req/s wall; "
          f"{v4['req_per_vsec']:.0f} req/vsec virtual (32 slots); "
          f"recompiles_after_warmup={w4['recompiles']}; "
          f"bit_exact={w4['bit_exact']}; "
          f"bank_util={w4['scheduler']['bank_utilization']}", group="distributed")
    _emit("bench_distributed_scaling", 0,
          f"{vscale:.2f}x virtual-throughput scaling 1->4 banks "
          f"(modeled 8->32 MVU slots on the booked mixed W2A2+W4A8 "
          f"stream; >=2x required); wall {wscale:.2f}x on this host "
          f"({r['n_devices']} fake devices over {r['cpu_count']} cores)",
          group="distributed")
    sh = r["sharded"]
    _emit("bench_distributed_sharded_batch", 1e6 / sh["img_s_n"],
          f"batch {sh['batch']} sharded over 4 banks: "
          f"{sh['img_s_n']:.0f} img/s vs {sh['img_s_1']:.0f} single-device "
          f"({sh['img_s_n']/sh['img_s_1']:.2f}x wall); "
          f"bit_exact={sh['bit_exact']}", group="distributed")
    pl = r["pipelined"]
    _emit("bench_distributed_pipeline", 1e6 / pl["img_s"],
          f"{pl['img_s']:.0f} img/s over {len(pl['stages'])} pipeline "
          f"stages (steps {pl['stages']}); bit_exact={pl['bit_exact']}",
          group="distributed")


def bench_calibration():
    """Measured profiler + cost-model calibration gates (EXPERIMENTS.md
    §Calibration):

    - per-step profile of a small compiled W2A2 CNN, then the fitted
      ns-per-virtual-cycle and max |relative residual| of the cost model
      (both trajectory-tracked scalars);
    - measured tile re-rank: the measured winner is never slower than
      the analytic choice (``never_slower=True`` gated in ``derived``);
    - profiler off-path: plain serving runs emit zero measured spans —
      the profiler is opt-in (``measured_spans=0`` gated in ``derived``).
    """
    import jax
    import jax.numpy as jnp
    from repro.compiler import compile_graph
    from repro.core import bitops
    from repro.core.bitserial import SerialSpec, plan_spec
    from repro.kernels import tuning
    from repro.kernels.bitserial_matmul import bitserial_matmul_v2_pallas
    from repro.models.layers import QuantPolicy
    from repro.obs import Tracer, chrome_trace, fit, profile_program

    # --- profile a compiled Program and fit the calibration ------------
    # three serial layers (two convs + gemm) so the per-kind fit has
    # multiple conv samples and the residual row is non-trivial
    from repro.compiler import Graph, Node
    rng = np.random.RandomState(11)
    g = Graph(
        "calib_cnn", {"x": (None, 8, 8, 8)}, ["y"],
        [Node("c1", "conv2d", ["x", "c1.w"], "c1.y",
              {"stride": 1, "padding": 1}),
         Node("c1.relu", "relu", ["c1.y"], "c1.r"),
         Node("c2", "conv2d", ["c1.r", "c2.w"], "c2.y",
              {"stride": 1, "padding": 1}),
         Node("c2.relu", "relu", ["c2.y"], "c2.r"),
         Node("gap", "global_avg_pool", ["c2.r"], "pooled"),
         Node("fc", "gemm", ["pooled", "fc.w"], "y")],
        {"c1.w": (rng.randn(3, 3, 8, 16) * 0.2).astype(np.float32),
         "c2.w": (rng.randn(3, 3, 16, 16) * 0.2).astype(np.float32),
         "fc.w": (rng.randn(16, 10) * 0.2).astype(np.float32)})
    x = jnp.asarray(rng.rand(4, 8, 8, 8), jnp.float32)
    prog = compile_graph(g, x, policy=QuantPolicy(
        mode="serial", w_bits=2, a_bits=2, radix_bits=7), backend="xla")
    t0 = time.perf_counter()
    prof = profile_program(prog, batch=4, warmup=1, repeats=2)
    prof_us = (time.perf_counter() - t0) * 1e6
    cal = fit(prof)
    _emit("bench_calibration_profile", prof_us,
          f"{len(prof.steps)} steps profiled "
          f"({len(prof.serial_steps)} serial), warmup=1 best-of-2",
          group="calibration")
    _emit("bench_calibration_fit", cal.ns_for(),
          f"fitted ns/virtual-cycle (pooled, {cal.n_samples} samples; "
          "ns in us_per_call)", group="calibration")
    _emit("bench_calibration_residual", cal.max_abs_residual,
          f"max |rel residual|; outliers={list(cal.outliers)}",
          group="calibration")

    # --- measured tile re-rank: never slower than the analytic pick ----
    m, k, n = 64, 256, 128
    spec = SerialSpec(8, 4, True, True, 7)
    v2 = plan_spec(spec)
    rng = np.random.RandomState(3)
    wp = bitops.pack_bitplanes(bitops.pad_to(bitops.to_bitplanes(
        jnp.asarray(rng.randint(-8, 8, (k, n)).astype(np.int32)), 4),
        32, axis=1), axis=1)
    xp = bitops.pack_bitplanes(bitops.pad_to(bitops.to_bitplanes(
        jnp.asarray(rng.randint(-128, 128, (m, k)).astype(np.int32)), 8),
        32, axis=-1), axis=-1)
    scale = np.ones(n, np.float32)
    times: dict = {}

    def measure(cfg):
        key = tuple(sorted(cfg.kernel_kwargs().items()))
        if key not in times:
            fn = jax.jit(lambda xx, ww: bitserial_matmul_v2_pallas(
                xx, ww, scale, None, spec=v2, k=k, interpret=True,
                **cfg.kernel_kwargs()))
            jax.block_until_ready(fn(xp, wp))      # compile + warmup
            times[key] = _time_us(
                lambda: jax.block_until_ready(fn(xp, wp)), n=2) * 1e-6
        return times[key]

    tuning.clear_cache()
    analytic = tuning.choose_tile(m, k, n, spec)
    chosen = tuning.choose_tile_measured(m, k, n, spec, measure=measure,
                                         top_k=3)
    t_an, t_ch = measure(analytic), measure(chosen)
    _emit("bench_calibration_rerank", t_ch * 1e6,
          f"measured ({chosen.block_m},{chosen.block_n},{chosen.block_k})"
          f" vs analytic ({analytic.block_m},{analytic.block_n},"
          f"{analytic.block_k}) {t_an * 1e6:.0f}us over "
          f"{len(times)} timed tiles; never_slower={t_ch <= t_an}",
          group="calibration")

    # --- off-path: the profiler must cost nothing when not invoked -----
    tr = Tracer()
    jax.block_until_ready(prog(x))
    jax.block_until_ready(prog(x))
    trace = chrome_trace(tr)
    n_measured = sum(1 for ev in trace["traceEvents"]
                     if ev.get("pid") == "measured")
    _emit("bench_calibration_off_path", 0,
          f"measured_spans={n_measured} "
          f"buffered={tr.stats()['buffered']} (profiler is opt-in)",
          group="calibration")


GROUPS = {
    "tables": [table2_model_sizes, table3_resnet9_cycles, table5_cnv_fps,
               table6_resnet50],
    "kernels": [bench_serial_matmul, bench_pallas_kernel, bench_tuner],
    "conv": [bench_conv_layers, bench_conv_pallas_kernel, bench_resnet9_e2e],
    "compile": [bench_compile_resnet9, bench_compile_dispatch],
    "serve": [bench_quantized_lm_serve],
    "serving": [bench_serving],
    "distributed": [bench_distributed],
    "coldstart": [bench_coldstart],
    "lm": [bench_lm],
    "obs": [bench_obs],
    "calibration": [bench_calibration],
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench groups "
                         f"({'/'.join(GROUPS)}); default: all")
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="path for the machine-readable dump "
                         "('' disables)")
    ap.add_argument("--conv-json", default="BENCH_conv.json",
                    help="path for the conv/ResNet9 rows dump "
                         "('' disables)")
    ap.add_argument("--compile-json", default="BENCH_compile.json",
                    help="path for the graph-compiler rows dump "
                         "('' disables)")
    ap.add_argument("--serving-json", default="BENCH_serving.json",
                    help="path for the serving-runtime rows dump "
                         "('' disables)")
    ap.add_argument("--distributed-json", default="BENCH_distributed.json",
                    help="path for the bank-scaling rows dump "
                         "('' disables)")
    ap.add_argument("--coldstart-json", default="BENCH_coldstart.json",
                    help="path for the artifact warm-boot rows dump "
                         "('' disables)")
    ap.add_argument("--lm-json", default="BENCH_lm.json",
                    help="path for the continuous-batching LM rows dump "
                         "('' disables)")
    ap.add_argument("--obs-json", default="BENCH_obs.json",
                    help="path for the observability overhead rows dump "
                         "('' disables)")
    ap.add_argument("--calibration-json", default="BENCH_calibration.json",
                    help="path for the profiler/calibration rows dump "
                         "('' disables)")
    args = ap.parse_args(argv)
    groups = list(GROUPS) if not args.only else [
        g.strip() for g in args.only.split(",") if g.strip()]
    unknown = [g for g in groups if g not in GROUPS]
    if unknown:
        ap.error(f"unknown bench group(s) {unknown}; "
                 f"choose from {list(GROUPS)}")
    print("name,us_per_call,derived")
    for g in groups:
        for fn in GROUPS[g]:
            fn()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(_ROWS, f, indent=1, sort_keys=True)
        print(f"# wrote {len(_ROWS)} rows to {args.json}")
    group_paths = {"conv": args.conv_json, "compile": args.compile_json,
                   "serving": args.serving_json,
                   "distributed": args.distributed_json,
                   "coldstart": args.coldstart_json,
                   "lm": args.lm_json,
                   "obs": args.obs_json,
                   "calibration": args.calibration_json}
    for grp, path in group_paths.items():
        keys = _GROUP_KEYS[grp]
        if not path or not keys:
            continue
        rows = {k: _ROWS[k] for k in keys}
        with open(path, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
