"""Benchmark harness — one function per paper table + kernel micro-bench +
roofline summary. Prints ``name,us_per_call,derived`` CSV rows.

Run: PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import time
import timeit

import numpy as np


def _time_us(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    t = timeit.timeit(fn, number=n)
    return t / n * 1e6


def table2_model_sizes():
    """Paper Table 2: ResNet9 model sizes (fp32 vs int2 packed)."""
    import jax
    import jax.numpy as jnp
    from repro.core.codegen import export_weights
    from repro.models.resnet import ResNet9Config, resnet9_init
    cfg = ResNet9Config()
    t0 = time.time()
    params = resnet9_init(jax.random.PRNGKey(0), cfg)
    conv = {n: params[n]["w"] for n, *_ in cfg.layers}
    exported = export_weights(conv, w_bits=2)
    packed = sum(v.packed.nbytes for v in exported.values())
    fp32 = sum(params[n]["w"].nbytes for n, *_ in cfg.layers)
    us = (time.time() - t0) * 1e6
    # paper: Plain-CNN fp32 18912487 B, Int2 1181360 B
    print(f"table2_fp32_bytes,{us:.0f},{fp32} (paper 18912487)")
    print(f"table2_int2_bytes,{us:.0f},{packed} (paper 1181360)")
    print(f"table2_compression,{us:.0f},{fp32/packed:.1f}x")


def table3_resnet9_cycles():
    """Paper Table 3: per-layer ResNet9 cycles at W2/A2."""
    import repro.core.cost_model as cm
    t0 = time.time()
    cyc = cm.network_cycles(cm.RESNET9_CIFAR10, 2, 2, edge="paper_edge")
    named = {l.name: c for l, c in zip(cm.RESNET9_CIFAR10, cyc)}
    us = (time.time() - t0) * 1e6
    exact = 0
    for k, v in cm.RESNET9_PAPER_CYCLES.items():
        match = named[k] == v
        exact += match
        print(f"table3_{k},{us:.0f},{named[k]} (paper {v} "
              f"{'EXACT' if match else 'dev'})")
    total = sum(cyc)
    print(f"table3_total,{us:.0f},{total} (paper {cm.RESNET9_PAPER_TOTAL} "
          f"{'EXACT' if total == cm.RESNET9_PAPER_TOTAL else ''}) "
          f"[{exact}/8 layers exact]")
    # the other edge variants, for the reconciliation note
    for edge in ("dense", "pad_skip"):
        t = sum(cm.network_cycles(cm.RESNET9_CIFAR10, 2, 2, edge=edge))
        print(f"table3_total_{edge},{us:.0f},{t}")


def table5_cnv_fps():
    """Paper Table 5: CNV throughput vs precision (scaling law)."""
    import repro.core.cost_model as cm
    t0 = time.time()
    us = (time.time() - t0) * 1e6
    for (w, a), paper in cm.CNV_PAPER_FPS.items():
        fps = cm.pipelined_fps(cm.CNV_CIFAR10, a, w)
        print(f"table5_cnv_W{w}A{a},{us:.0f},{fps:.0f} FPS "
              f"(paper {paper}; ratio {fps/paper:.2f})")
    f11 = cm.pipelined_fps(cm.CNV_CIFAR10, 1, 1)
    f22 = cm.pipelined_fps(cm.CNV_CIFAR10, 2, 2)
    print(f"table5_scaling_1x1_over_2x2,{us:.0f},{f11/f22:.2f} (paper 4.00)")


def table6_resnet50():
    """Paper Table 6: ResNet-50 FPS and FPS/W."""
    import repro.core.cost_model as cm
    t0 = time.time()
    layers = cm.resnet50_layers()
    fps_d = cm.distributed_fps(layers, 2, 1, edge="paper_edge")
    fps_p = cm.pipelined_fps(layers, 2, 1, edge="paper_edge")
    us = (time.time() - t0) * 1e6
    hw = cm.HWConfig()
    print(f"table6_resnet50_fps,{us:.0f},{fps_d:.0f} "
          f"(paper {cm.RESNET50_PAPER['fps']}; distributed-mode estimate)")
    print(f"table6_resnet50_fps_per_watt,{us:.0f},{fps_d/hw.power_w:.1f} "
          f"(paper {cm.RESNET50_PAPER['fps_per_watt']}; FILM-QNN 8.4)")
    print(f"table6_resnet50_fps_pipelined,{us:.0f},{fps_p:.0f}")


def bench_serial_matmul():
    """Micro-bench: serial matmul XLA path vs float matmul (CPU timings are
    indicative only; the TPU target uses the Pallas kernel)."""
    import jax
    import jax.numpy as jnp
    from repro.core import bitops
    from repro.core.bitserial import SerialSpec, serial_matmul_packed
    rng = np.random.RandomState(0)
    m, k, n = 64, 1024, 1024
    x = jnp.asarray(rng.randint(-128, 128, (m, k)), jnp.int32)
    w = rng.randint(-8, 8, (k, n)).astype(np.int32)
    planes = bitops.pad_to(bitops.to_bitplanes(jnp.asarray(w), 4), 32, axis=1)
    wp = bitops.pack_bitplanes(planes, axis=1)
    xf = jnp.asarray(rng.randn(m, k), jnp.float32)
    wf = jnp.asarray(rng.randn(k, n), jnp.float32)

    f_float = jax.jit(lambda a, b: a @ b)
    for radix, name in ((1, "bitserial_r2"), (7, "digitserial_r128")):
        spec = SerialSpec(8, 4, True, True, radix)
        f = jax.jit(lambda xx, ww, s=spec: serial_matmul_packed(
            xx, ww, spec=s, k=k))
        us = _time_us(lambda: jax.block_until_ready(f(x, wp)))
        print(f"bench_{name}_W4A8_{m}x{k}x{n},{us:.0f},"
              f"{spec.num_plane_products} plane products")
    us_f = _time_us(lambda: jax.block_until_ready(f_float(xf, wf)))
    print(f"bench_float_matmul_{m}x{k}x{n},{us_f:.0f},fp32 reference")


def bench_pallas_kernel():
    """Pallas kernel in interpret mode (correctness-path timing)."""
    import jax
    import jax.numpy as jnp
    from repro.core import bitops
    from repro.core.bitserial import SerialSpec
    from repro.kernels.bitserial_matmul import bitserial_matmul_pallas
    rng = np.random.RandomState(0)
    m, k, n = 16, 256, 64
    x = jnp.asarray(rng.randint(-8, 8, (m, k)), jnp.int32)
    w = rng.randint(-8, 8, (k, n)).astype(np.int32)
    planes = bitops.pad_to(bitops.to_bitplanes(jnp.asarray(w), 4), 32, axis=1)
    wp = bitops.pack_bitplanes(planes, axis=1)
    scale = np.ones(n, np.float32)
    spec = SerialSpec(4, 4, True, True, 7)
    fn = jax.jit(lambda xx, ww: bitserial_matmul_pallas(
        xx, ww, scale, None, spec=spec, k=k, block_m=16, block_n=32,
        block_k=64, interpret=True))
    us = _time_us(lambda: jax.block_until_ready(fn(x, wp)), n=3)
    print(f"bench_pallas_interpret_W4A4_{m}x{k}x{n},{us:.0f},"
          "interpret mode (TPU kernel validated vs ref)")


def bench_quantized_lm_serve():
    """Tokens/s of the smoke LM through the full quantized serve path."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.launch.serve import GenRequest, Server
    cfg = get_arch("stablelm-1.6b").smoke
    server = Server(cfg, batch_slots=2, max_len=48)
    rng = np.random.RandomState(0)
    reqs = [GenRequest(rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32),
                       8) for _ in range(2)]
    server.generate(reqs)  # warmup/compile
    t0 = time.time()
    out = server.generate(reqs)
    dt = time.time() - t0
    ntok = sum(len(r.out_tokens) for r in out)
    print(f"bench_lm_serve_W4A8,{dt/max(ntok,1)*1e6:.0f},"
          f"{ntok/dt:.1f} tok/s (smoke cfg, CPU)")


def roofline_summary():
    """Summary of the dry-run roofline table (details in EXPERIMENTS.md)."""
    try:
        from benchmarks.roofline import table
    except ImportError:
        from roofline import table  # run as a script
    rows = table()
    if not rows:
        print("roofline_cells,0,no dryrun artifacts found")
        return
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    worst = min(rows, key=lambda r: r["roofline_frac"])
    best = max(rows, key=lambda r: r["roofline_frac"])
    print(f"roofline_cells,0,{len(rows)} cells; dominant terms {n_dom}")
    print(f"roofline_worst,0,{worst['arch']}/{worst['shape']}/{worst['mesh']}"
          f" frac={worst['roofline_frac']:.3f}")
    print(f"roofline_best,0,{best['arch']}/{best['shape']}/{best['mesh']}"
          f" frac={best['roofline_frac']:.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    table2_model_sizes()
    table3_resnet9_cycles()
    table5_cnv_fps()
    table6_resnet50()
    bench_serial_matmul()
    bench_pallas_kernel()
    bench_quantized_lm_serve()
    roofline_summary()


if __name__ == "__main__":
    main()
