"""Generate the EXPERIMENTS.md benchmark tables (§Serving, §Distributed,
§LM-serving, §Observability, §Calibration, §History) from the
``BENCH_*.json`` artifacts a ``benchmarks.run`` invocation leaves behind:

  PYTHONPATH=src python -m benchmarks.run
  PYTHONPATH=src python -m benchmarks.report > artifacts/bench_report.md

Each table is silently skipped when its artifact is absent, so partial
runs (``--only serving``) still report cleanly.
"""

from __future__ import annotations

import json
import os

try:
    from benchmarks.history import DEFAULT_HISTORY, load_history
except ImportError:
    from history import DEFAULT_HISTORY, load_history


def serving_table(path="BENCH_serving.json"):
    """Aggregate the serving-runtime benchmark artifact (emitted by
    ``benchmarks.run --only serving``) into the EXPERIMENTS.md §Serving
    table; silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §Serving — bucketed runtime vs re-jit-per-shape\n")
    print("| row | us/req | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    sp = rows.get("bench_serving_speedup", {}).get("derived", "")
    if sp:
        print(f"\nHeadline: **{sp.split(' ')[0]}** bucketed steady-state "
              "vs the seed's re-jit-per-shape serving discipline.")


def distributed_table(path="BENCH_distributed.json"):
    """Aggregate the bank-scaling artifact (emitted by ``benchmarks.run
    --only distributed``) into the EXPERIMENTS.md §Distributed table;
    silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §Distributed — serving across a mesh of MVU banks\n")
    print("| row | us/req | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    sc = rows.get("bench_distributed_scaling", {}).get("derived", "")
    if sc:
        print(f"\nHeadline: **{sc.split(' ')[0]}** virtual-throughput "
              "scaling from 1 to 4 banks on the mixed-precision stream.")


def lm_table(path="BENCH_lm.json"):
    """Aggregate the continuous-batching LM artifact (emitted by
    ``benchmarks.run --only lm``) into the EXPERIMENTS.md §LM-serving
    table; silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §LM-serving — continuous batching vs static chunks\n")
    print("| row | us/token | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    sp = rows.get("bench_lm_speedup", {}).get("derived", "")
    if sp:
        print(f"\nHeadline: **{sp.split(' ')[0]}** tokens/s on the "
              "heterogeneous stream, token-granular join/leave vs "
              "decode-to-the-longest chunks.")


def obs_table(path="BENCH_obs.json"):
    """Aggregate the observability-overhead artifact (emitted by
    ``benchmarks.run --only obs``) into the EXPERIMENTS.md §Observability
    table; silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §Observability — tracing/metrics overhead on the "
          "serving smoke\n")
    print("| row | us/req (ns for _ns rows) | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    ov = rows.get("bench_obs_tracing_overhead_pct", {}).get("derived", "")
    if ov:
        print(f"\nHeadline: **{ov.split(' ')[0]}** throughput cost of "
              "full request tracing (sample_every=1) on the serving "
              "smoke; disabled-mode metric writes are one flag check.")


def calibration_table(path="BENCH_calibration.json"):
    """Aggregate the profiler/calibration artifact (emitted by
    ``benchmarks.run --only calibration``) into the EXPERIMENTS.md
    §Calibration table; silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §Calibration — measured profiler vs the cycle cost "
          "model\n")
    print("| row | value (us unless noted) | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.1f} | {r['derived']} |")
    fitted = rows.get("bench_calibration_fit", {}).get("us_per_call")
    if fitted is not None:
        print(f"\nHeadline: **{fitted:.1f} ns/virtual-cycle** fitted on "
              "this host; the scheduler's wall-time finish estimates use "
              "this instead of the nominal 250 MHz clock once a "
              "calibration is installed.")


def history_table(path=DEFAULT_HISTORY, *, tail=5):
    """Tail of the benchmark history log (``BENCH_history.jsonl``) so a
    report shows the trajectory, not just the latest numbers."""
    records = load_history(path)
    if not records:
        return
    print(f"\n### §History — last {min(tail, len(records))} of "
          f"{len(records)} benchmark-history records\n")
    print("| ts (UTC) | git sha | metrics | host |")
    print("|---|---|---|---|")
    for rec in records[-tail:]:
        host = rec.get("host") or {}
        print(f"| {str(rec.get('ts', ''))[:19]} | "
              f"{str(rec.get('git_sha') or '-')[:12]} | "
              f"{len(rec.get('metrics', {}))} | "
              f"{host.get('machine', '-')}/{host.get('cpus', '-')}cpu |")
    print("\nGate: `python -m benchmarks.regress` compares the newest "
          "record against the median of prior same-host records.")


def main():
    print("<!-- generated by benchmarks/report.py from BENCH_*.json "
          "artifacts -->")
    serving_table()
    distributed_table()
    lm_table()
    obs_table()
    calibration_table()
    history_table()


if __name__ == "__main__":
    main()
