"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dryrun artifacts. Run after `python -m repro.launch.dryrun --all --mesh both`:

  PYTHONPATH=src python -m benchmarks.report > artifacts/roofline_report.md
"""

from __future__ import annotations

import glob
import json
import os

try:
    from benchmarks.roofline import (ART_DIR, load_records, roofline_terms,
                                     model_flops)
except ImportError:
    from roofline import ART_DIR, load_records, roofline_terms, model_flops


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs):
    print("\n### §Dry-run — lower+compile per (arch × shape × mesh)\n")
    print("| arch | shape | mesh | devs | status | compile_s | HLO FLOPs/dev "
          "| HBM proxy/dev | arg bytes/dev | collective bytes/dev | "
          "dominant collective |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} |  | "
                  f"**FAIL** {r.get('error','')[:60]} | | | | | | |")
            continue
        cb = r["collectives"]["bytes"]
        dom = max(cb, key=cb.get) if any(cb.values()) else "-"
        arg = r.get("mem", {}).get("argument_size_in_bytes", 0) or 0
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{r['n_devices']} | OK | {r['compile_s']} | "
              f"{r['flops']:.2e} | {fmt_bytes(r['bytes_hbm'])} | "
              f"{fmt_bytes(arg)} | "
              f"{fmt_bytes(r['collectives']['total_bytes'])} | {dom} |")


def roofline_table(recs):
    print("\n### §Roofline — three terms per cell (single-pod, 256 chips)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| MODEL_FLOPS | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok") or r["mesh"] != "single":
            continue
        t = roofline_terms(r)
        print(f"| {t['arch']} | {t['shape']} | {t['t_compute_s']:.4f} | "
              f"{t['t_memory_s']:.4f} | {t['t_collective_s']:.4f} | "
              f"**{t['dominant']}** | {t['model_flops']:.2e} | "
              f"{t['useful_ratio']:.2f} | {t['roofline_frac']:.3f} |")


def delta_table(recs, base_dir):
    """Baseline (pre-optimization snapshot) vs optimized, per cell."""
    base = {(r["arch"], r["shape"], r["mesh"]): r
            for r in load_records(base_dir) if r.get("ok")}
    if not base:
        return
    print("\n### §Perf — baseline vs optimized, all cells (single-pod)\n")
    print("NOTE: baseline artifacts were analyzed before the DUS-aware "
          "accounting fix, so decode/prefill HBM deltas include ~2x of "
          "accounting correction on top of the real optimizations "
          "(itemized separately in EXPERIMENTS.md §Perf).\n")
    print("| arch | shape | FLOPs/dev Δ | HBM proxy Δ | collective Δ |")
    print("|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok") or r["mesh"] != "single":
            continue
        b = base.get((r["arch"], r["shape"], r["mesh"]))
        if b is None:
            continue

        def ratio(k, sub=None):
            x = b[k] if sub is None else b[k][sub]
            y = r[k] if sub is None else r[k][sub]
            if not x or not y:
                return "-"
            f = x / y
            return f"{f:.2f}x" if f >= 1.005 else (
                f"{1/f:.2f}x worse" if f < 0.995 else "=")

        print(f"| {r['arch']} | {r['shape']} | {ratio('flops')} | "
              f"{ratio('bytes_hbm')} | "
              f"{ratio('collectives', 'total_bytes')} |")


def serving_table(path="BENCH_serving.json"):
    """Aggregate the serving-runtime benchmark artifact (emitted by
    ``benchmarks.run --only serving``) into the EXPERIMENTS.md §Serving
    table; silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §Serving — bucketed runtime vs re-jit-per-shape\n")
    print("| row | us/req | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    sp = rows.get("bench_serving_speedup", {}).get("derived", "")
    if sp:
        print(f"\nHeadline: **{sp.split(' ')[0]}** bucketed steady-state "
              "vs the seed's re-jit-per-shape serving discipline.")


def distributed_table(path="BENCH_distributed.json"):
    """Aggregate the bank-scaling artifact (emitted by ``benchmarks.run
    --only distributed``) into the EXPERIMENTS.md §Distributed table;
    silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §Distributed — serving across a mesh of MVU banks\n")
    print("| row | us/req | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    sc = rows.get("bench_distributed_scaling", {}).get("derived", "")
    if sc:
        print(f"\nHeadline: **{sc.split(' ')[0]}** virtual-throughput "
              "scaling from 1 to 4 banks on the mixed-precision stream.")


def lm_table(path="BENCH_lm.json"):
    """Aggregate the continuous-batching LM artifact (emitted by
    ``benchmarks.run --only lm``) into the EXPERIMENTS.md §LM-serving
    table; silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §LM-serving — continuous batching vs static chunks\n")
    print("| row | us/token | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    sp = rows.get("bench_lm_speedup", {}).get("derived", "")
    if sp:
        print(f"\nHeadline: **{sp.split(' ')[0]}** tokens/s on the "
              "heterogeneous stream, token-granular join/leave vs "
              "decode-to-the-longest chunks.")


def obs_table(path="BENCH_obs.json"):
    """Aggregate the observability-overhead artifact (emitted by
    ``benchmarks.run --only obs``) into the EXPERIMENTS.md §Observability
    table; silently skipped when the artifact is absent."""
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### §Observability — tracing/metrics overhead on the "
          "serving smoke\n")
    print("| row | us/req (ns for _ns rows) | derived |")
    print("|---|---|---|")
    for name in sorted(rows):
        r = rows[name]
        print(f"| {name} | {r['us_per_call']:.0f} | {r['derived']} |")
    ov = rows.get("bench_obs_tracing_overhead_pct", {}).get("derived", "")
    if ov:
        print(f"\nHeadline: **{ov.split(' ')[0]}** throughput cost of "
              "full request tracing (sample_every=1) on the serving "
              "smoke; disabled-mode metric writes are one flag check.")


def main():
    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    fail = [r for r in recs if not r.get("ok")]
    print(f"<!-- generated by benchmarks/report.py: {len(ok)} OK, "
          f"{len(fail)} FAIL -->")
    dryrun_table(recs)
    roofline_table(recs)
    delta_table(recs, os.path.join(ART_DIR, "..", "dryrun_baseline"))
    serving_table()
    distributed_table()
    lm_table()
    obs_table()


if __name__ == "__main__":
    main()
