"""Per-cell dry-run profiler: lowers one (arch × shape × mesh) cell and
prints the top dot and collective contributors with their while-loop
multiplicities — the §Perf "profile" used for hypothesis forming.

  PYTHONPATH=src python -m benchmarks.profile_cell --arch qwen3-moe-235b-a22b \
      --shape train_4k --mesh single --top 12
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import re

import jax

from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.distributed.context import bind_axes
from repro.distributed.sharding import dp_axes_of
from repro.launch import hlo_analysis as H


def comp_constants(txt):
    comp_consts, cur = {}, None
    for raw in txt.splitlines():
        line = raw.strip()
        m = re.match(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$",
                     line)
        if m and "=" not in line.split("(")[0]:
            cur = m.group(2)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur:
            cm = re.search(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)", line)
            if cm:
                comp_consts.setdefault(cur, []).append(int(cm.group(1)))
    return comp_consts


def profile(txt, top=12):
    comps, entry = H._parse(txt)
    consts = comp_constants(txt)

    def cond_trip(cond):
        vals, stack, seen = [], [cond], set()
        while stack:
            c = stack.pop()
            if c in seen or c not in comps:
                continue
            seen.add(c)
            vals.extend(consts.get(c, []))
            for op in comps[c].ops:
                for _, cal in H._called(op):
                    stack.append(cal)
        vals = [v for v in vals if 0 < v < 10_000_000]
        return max(vals) if vals else 1

    dots, colls = [], []

    def visit(cname, mult):
        comp = comps[cname]
        for op in comp.ops:
            if op.kind in ("dot", "dot-general"):
                f = H._dot_flops(op, comp)
                dots.append((f * mult, f, mult, op.sig[:48], cname[:48]))
            base = op.kind.replace("-start", "")
            if base in H._COLLECTIVES and not op.kind.endswith("-done"):
                nb = H._bytes_of(op.sig)
                colls.append((nb * mult, nb, mult, base, op.sig[:48],
                              cname[:40]))
            calls = H._called(op)
            if op.kind == "while":
                body = next((c for k, c in calls if k == "body"), None)
                cond = next((c for k, c in calls if k == "condition"), None)
                t = cond_trip(cond) if cond else 1
                if body:
                    visit(body, mult * t)
            elif op.kind == "conditional":
                brs = [c for k, c in calls if k == "branch"]
                for b in brs[:1]:
                    visit(b, mult)
            elif op.kind in ("fusion", "call", "async-start"):
                for k, cal in calls:
                    if k == "calls" and cal in comps:
                        visit(cal, mult)

    visit(entry, 1)
    dots.sort(reverse=True)
    colls.sort(reverse=True)
    print(f"== dots: total {sum(d[0] for d in dots):.3e} flops/dev ==")
    for d in dots[:top]:
        print(f"  {d[0]:.2e} = {d[1]:.2e} x{d[2]:5d}  {d[3]:48s} {d[4]}")
    print(f"== collectives: total {sum(c[0] for c in colls):.3e} B/dev ==")
    for c in colls[:top]:
        print(f"  {c[0]:.2e} = {c[1]:.2e} x{c[2]:5d}  {c[3]:18s} {c[4]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--radix", type=int, default=7)
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    fn, inputs, shardings, cfg, kw = build_cell(args.arch, args.shape,
                                                radix=args.radix)
    with mesh, bind_axes(dp=dp_axes_of(mesh), tp="model", mesh=mesh):
        txt = jax.jit(fn, in_shardings=shardings(mesh), **kw) \
            .lower(*inputs).compile().as_text()
    profile(txt, args.top)


if __name__ == "__main__":
    main()
