"""Benchmark history: append each run's gated scalars to a JSONL log.

Every ``benchmarks.run`` invocation overwrites its ``BENCH_*.json``
artifacts — fine for "what is the number now", useless for "is the
number drifting". This module flattens all current artifacts into one
record (``{"<group>.<row>": us_per_call}``) stamped with the git sha,
UTC timestamp, and a host fingerprint (timings from different hosts are
not comparable — the regression checker partitions on it), and appends
it to ``BENCH_history.jsonl``. CI restores the log from its cache, so
the trajectory accumulates across runs; :mod:`benchmarks.regress` gates
the latest record against a noise-aware rolling baseline.

    PYTHONPATH=src python -m benchmarks.history          # append
    PYTHONPATH=src python -m benchmarks.regress          # gate
"""

from __future__ import annotations

import argparse
import datetime
import glob
import json
import os
import platform
import subprocess
from typing import Dict, List, Optional

SCHEMA_VERSION = 1
DEFAULT_HISTORY = "BENCH_history.jsonl"
BENCH_GLOB = "BENCH_*.json"


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def host_fingerprint() -> Dict:
    """Coarse host identity: enough to partition incomparable timing
    populations (different CPU / python), not to identify a machine."""
    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpus": os.cpu_count(),
    }


def collect_metrics(paths: Optional[List[str]] = None,
                    pattern: str = BENCH_GLOB) -> Dict[str, float]:
    """Flatten every BENCH_*.json into ``{"<group>.<row>": us_per_call}``
    (the gated scalars; ``derived`` strings are for humans)."""
    if paths is None:
        paths = sorted(glob.glob(pattern))
    metrics: Dict[str, float] = {}
    for path in paths:
        stem = os.path.basename(path)
        if stem.startswith("BENCH_"):
            stem = stem[len("BENCH_"):]
        stem = stem.rsplit(".json", 1)[0]
        try:
            rows = json.load(open(path))
        except (OSError, ValueError):
            continue          # unreadable artifact: skip, don't poison
        if not isinstance(rows, dict):
            continue
        for name, r in rows.items():
            try:
                metrics[f"{stem}.{name}"] = float(r["us_per_call"])
            except (KeyError, TypeError, ValueError):
                continue
    return metrics


def make_record(paths: Optional[List[str]] = None, *,
                pattern: str = BENCH_GLOB) -> Dict:
    return {
        "schema": SCHEMA_VERSION,
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "git_sha": git_sha(),
        "host": host_fingerprint(),
        "metrics": collect_metrics(paths, pattern),
    }


def append_record(record: Dict, path: str = DEFAULT_HISTORY) -> str:
    with open(path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    return path


def load_history(path: str = DEFAULT_HISTORY) -> List[Dict]:
    """All records, oldest first; corrupt lines are skipped (a truncated
    append from a killed run must not wedge the gate forever)."""
    if not os.path.exists(path):
        return []
    out: List[Dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if isinstance(rec, dict) and isinstance(rec.get("metrics"),
                                                    dict):
                out.append(rec)
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.history",
        description="append the current BENCH_*.json scalars to the "
                    "benchmark history log")
    ap.add_argument("--history", default=DEFAULT_HISTORY)
    ap.add_argument("--glob", default=BENCH_GLOB,
                    help="artifact pattern to flatten")
    args = ap.parse_args(argv)
    rec = make_record(pattern=args.glob)
    if not rec["metrics"]:
        print(f"history: no {args.glob} artifacts found — nothing to "
              "append (run `python -m benchmarks.run` first)")
        return 1
    append_record(rec, args.history)
    n = len(load_history(args.history))
    print(f"history: appended {len(rec['metrics'])} metrics "
          f"(sha={str(rec['git_sha'])[:12]}) -> {args.history} "
          f"({n} records)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
