"""Noise-aware benchmark regression gate over ``BENCH_history.jsonl``.

The latest record is compared metric-by-metric against a rolling
baseline: the **median** of up to ``--baseline-n`` prior records (median
because one noisy CI run must not move the bar). A metric regresses when
it is worse than baseline by more than a relative threshold AND by more
than an absolute noise floor (sub-noise rows flap on pure percentages).
All ``us_per_call`` scalars are lower-is-better; per-metric threshold
overrides live in :data:`THRESHOLDS`.

Hosts differ: only prior records with the same host fingerprint as the
latest participate in its baseline. Too-short history is reported but
passes (the gate needs evidence before it can fail anyone).

    PYTHONPATH=src python -m benchmarks.regress            # exit 1 on
                                                           # regression
"""

from __future__ import annotations

import argparse
import statistics
from typing import Dict, List, Optional

try:
    from benchmarks.history import DEFAULT_HISTORY, load_history
except ImportError:
    from history import DEFAULT_HISTORY, load_history

DEFAULT_THRESHOLD = 0.5   # +50%: CPU CI timing noise is real
BASELINE_N = 5            # rolling window of prior records
MIN_HISTORY = 3           # records (incl. latest) before the gate arms
EPS_US = 5.0              # absolute noise floor for us_per_call rows

# per-metric relative-threshold overrides (keys as in history records:
# "<group>.<row>"). Percentage/ratio-valued gate rows swing with host
# load far more than steady-state timings do.
THRESHOLDS: Dict[str, float] = {
    "obs.bench_obs_tracing_overhead_pct": 2.0,
    "obs.bench_obs_counter_inc_enabled_ns": 2.0,
    "obs.bench_obs_counter_inc_disabled_ns": 2.0,
    "calibration.bench_calibration_fit": 2.0,
    "calibration.bench_calibration_residual": 2.0,
}


def _same_host(a: Dict, b: Dict) -> bool:
    ha, hb = a.get("host") or {}, b.get("host") or {}
    return (ha.get("platform"), ha.get("machine")) == \
        (hb.get("platform"), hb.get("machine"))


def detect(history: List[Dict], *, baseline_n: int = BASELINE_N,
           threshold: float = DEFAULT_THRESHOLD,
           min_history: int = MIN_HISTORY,
           eps_us: float = EPS_US,
           thresholds: Optional[Dict[str, float]] = None) -> Dict:
    """Gate the newest record against the rolling baseline.

    Returns ``{"status": "ok" | "regressions" | "insufficient",
    "regressions": [...], "checked": N, "baseline_records": N}``.
    """
    thresholds = THRESHOLDS if thresholds is None else thresholds
    if len(history) < min_history:
        return {"status": "insufficient", "regressions": [],
                "checked": 0, "baseline_records": max(0, len(history) - 1)}
    latest = history[-1]
    prior = [r for r in history[:-1] if _same_host(r, latest)]
    prior = prior[-baseline_n:]
    if len(prior) < max(2, min_history - 1):
        return {"status": "insufficient", "regressions": [],
                "checked": 0, "baseline_records": len(prior)}

    regressions = []
    checked = 0
    for metric, value in sorted(latest.get("metrics", {}).items()):
        base_vals = [r["metrics"][metric] for r in prior
                     if metric in r.get("metrics", {})]
        if len(base_vals) < 2:
            continue                  # new metric: no baseline yet
        baseline = statistics.median(base_vals)
        checked += 1
        th = thresholds.get(metric, threshold)
        # lower-is-better scalars: regress on upward drift only
        if value > baseline * (1.0 + th) and value - baseline > eps_us:
            regressions.append({
                "metric": metric,
                "value": round(value, 3),
                "baseline": round(baseline, 3),
                "ratio": round(value / baseline, 3) if baseline else None,
                "threshold": th,
                "baseline_n": len(base_vals),
            })
    return {"status": "regressions" if regressions else "ok",
            "regressions": regressions, "checked": checked,
            "baseline_records": len(prior)}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.regress",
        description="gate the latest benchmark-history record against "
                    "the rolling median baseline (exit 1 on regression)")
    ap.add_argument("--history", default=DEFAULT_HISTORY)
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="default relative worsening tolerated")
    ap.add_argument("--baseline-n", type=int, default=BASELINE_N)
    ap.add_argument("--min-history", type=int, default=MIN_HISTORY)
    ap.add_argument("--eps-us", type=float, default=EPS_US,
                    help="absolute noise floor (us) a regression must "
                         "also exceed")
    args = ap.parse_args(argv)
    history = load_history(args.history)
    if not history:
        print(f"regress: no history at {args.history} — run "
              "`python -m benchmarks.history` after a bench run")
        return 2
    rep = detect(history, baseline_n=args.baseline_n,
                 threshold=args.threshold, min_history=args.min_history,
                 eps_us=args.eps_us)
    if rep["status"] == "insufficient":
        print(f"regress: insufficient history "
              f"({len(history)} records, {rep['baseline_records']} "
              f"comparable) — gate passes vacuously")
        return 0
    print(f"regress: checked {rep['checked']} metrics against the "
          f"median of {rep['baseline_records']} prior records")
    if rep["status"] == "ok":
        print("regress: no regressions")
        return 0
    for r in rep["regressions"]:
        print(f"REGRESSION {r['metric']}: {r['value']} vs baseline "
              f"{r['baseline']} ({r['ratio']}x, threshold "
              f"+{int(r['threshold'] * 100)}%)")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
