"""Roofline analysis from the dry-run artifacts.

Per (arch × shape × mesh) cell:
  compute term    = FLOPs_per_dev / peak  (bf16 197 TF/s; int dots at 394)
  memory term     = HBM-bytes_per_dev / 819 GB/s
  collective term = collective-bytes_per_dev / 45 GB/s link BW
plus MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the useful-compute
ratio MODEL_FLOPS / (HLO FLOPs × devices).

All quantities come from the call-graph roll-up (hlo_analysis) of the
compiled per-device module; the dominant term is the bottleneck the §Perf
loop iterates on.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
LINK_BW = 4.5e10

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# active params per arch (for MODEL_FLOPS): dense N; MoE: shared + top_k
# experts + attention/embeddings
_N_PARAMS = {
    "seamless-m4t-large-v2": 1.4e9,       # 24+24L enc-dec + 256k vocab emb
    "deepseek-v2-lite-16b": (15.7e9, 2.4e9),
    "qwen3-moe-235b-a22b": (235e9, 22e9),
    "mamba2-780m": 0.78e9,
    "command-r-plus-104b": 104e9,
    "nemotron-4-15b": 15e9,
    "stablelm-1.6b": 1.6e9,
    "qwen1.5-110b": 110e9,
    "internvl2-76b": 76e9,
    "hymba-1.5b": 1.5e9,
}

_SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128 * 1,
    "long_500k": 1 * 1,
}


def model_flops(arch: str, shape: str) -> float:
    n = _N_PARAMS.get(arch, 1e9)
    n_active = n[1] if isinstance(n, tuple) else n
    tokens = _SHAPE_TOKENS.get(shape, 1)
    mult = 6 if shape.startswith("train") else 2  # fwd-only when serving
    return mult * n_active * tokens


def load_records(art_dir: str = ART_DIR, tag: str = "") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def roofline_terms(rec: dict) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    fl = rec.get("flops", 0.0)
    fi = rec.get("flops_int", 0.0)
    n_dev = rec.get("n_devices", 1)
    # int dots run at 2x peak on the MXU
    t_compute = (fl - fi) / PEAK_BF16 + fi / PEAK_INT8
    t_memory = rec.get("bytes_hbm", 0.0) / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / LINK_BW
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = fl * n_dev
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    # ideal step time: the workload MUST do MODEL_FLOPS of math and MUST
    # stream its resident state (params + caches + opt, = per-device jit
    # argument bytes) through HBM at least once. The roofline fraction is
    # ideal / achieved-bound — 1.0 means the step runs at the hardware
    # limit of its intrinsic bottleneck.
    t_ideal_compute = mf / n_dev / PEAK_BF16
    arg_bytes = rec.get("mem", {}).get("argument_size_in_bytes", 0.0) or 0.0
    t_ideal_mem = arg_bytes / HBM_BW
    t_ideal = max(t_ideal_compute, t_ideal_mem)
    t_bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "radix": rec.get("radix"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "t_ideal_s": t_ideal,
        "roofline_frac": (t_ideal / t_bound) if t_bound > 0 else 0.0,
    }


def table(art_dir: str = ART_DIR, tag: str = "") -> List[dict]:
    out = []
    for rec in load_records(art_dir, tag):
        t = roofline_terms(rec)
        if t is not None:
            out.append(t)
    return out


def render(rows: List[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
            f"{r['t_collective_s']:10.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.2f} {r['roofline_frac']:9.3f}")
    return "\n".join(lines)


def main():
    rows = table()
    print(render(rows))
    print(f"\n{len(rows)} cells")


if __name__ == "__main__":
    main()
