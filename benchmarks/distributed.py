"""Bank-scaling benchmark worker: serving a mixed W2A2+W4A8 stream across
a mesh of MVU banks (one 8-slot bank per jax device).

Runs in its OWN process so it can force a multi-device host view before
jax initializes (``--xla_force_host_platform_device_count``); the harness
(:func:`benchmarks.run.bench_distributed`) spawns it and turns the JSON it
prints into ``BENCH_distributed.json`` rows.

Two scaling views are reported, deliberately separate:

* **virtual** — the barrel-controller cycle domain the repo's paper tables
  (Table 3/5) already model: the same canonical batch stream booked on 1
  bank (8 slots) vs 4 banks (32 slots) through the serving scheduler's
  least-finish placement. This is the paper's claim ("more banks on a
  bigger part → proportional throughput") measured on real compiled
  command streams, and is what the CI gate asserts ``>= 2x`` on.
* **wall** — end-to-end req/s of the live InferenceService at 1 vs 4
  banks on this host. On a CI box the fake host-platform devices all
  share a couple of physical cores (and XLA's intra-op thread pool
  already spreads the 1-bank run across them), so wall scaling is
  reported for honesty but NOT gated.
"""

import json
import os
import sys
import time

N_DEVICES = int(os.environ.get("BENCH_BANK_DEVICES", "8"))
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={N_DEVICES}")

import numpy as np  # noqa: E402


def build_registry():
    from repro.compiler.bench_graphs import tiny_mixed_cnn
    from repro.models.layers import QuantPolicy
    from repro.serving import ModelRegistry
    # the same canonical workload the mesh soak test measures
    g, calib = tiny_mixed_cnn()
    reg = ModelRegistry(backend="xla")
    k_lo = reg.register_graph("cnn", g, calib, QuantPolicy(
        mode="serial", w_bits=2, a_bits=2, radix_bits=7))
    k_hi = reg.register_graph("cnn", g, calib, QuantPolicy(
        mode="serial", w_bits=4, a_bits=8, radix_bits=7))
    return reg, k_lo, k_hi


BURST_SIZES = [1, 3, 16, 6, 9, 16]


def stream(keys, n_requests, seed=1):
    """The canonical mixed-precision client stream: (key, examples[])."""
    rng = np.random.RandomState(seed)
    out, i, total = [], 0, 0
    while total < n_requests:
        n = BURST_SIZES[i % len(BURST_SIZES)]
        xs = [rng.rand(8, 8, 8).astype(np.float32) for _ in range(n)]
        out.append((keys[i % 2], xs))
        total += n
        i += 1
    return out


def serve_wall(reg, keys, n_banks, n_requests=240):
    """Live service throughput at ``n_banks`` (wall clock) + metrics."""
    from repro.serving import InferenceService
    svc = InferenceService(reg, max_batch=16, max_wait_s=0.001,
                          max_queue=1024,
                          n_banks=None if n_banks == 1 else n_banks)
    bursts = stream(keys, n_requests)
    nreq = sum(len(xs) for _, xs in bursts)
    with svc:
        svc.warmup()
        warm = {k: v["compiles"]
                for k, v in svc.metrics()["bucket_caches"].items()}
        t0 = time.perf_counter()
        futs = []
        for key, xs in bursts:
            futs += svc.submit_many(key, xs)
        svc.drain(timeout=600)
        dt = time.perf_counter() - t0
        results = [np.asarray(f.result()) for f in futs]
        m = svc.metrics()
    recompiles = sum(v["compiles"] - warm[k]
                     for k, v in m["bucket_caches"].items())
    # spot-check bit-exactness vs direct single-device Program calls
    import jax.numpy as jnp
    flat = [(k, x) for k, xs in bursts for x in xs]
    bit_exact = True
    progs = {k: reg.program(k) for k in keys}
    for idx in range(0, nreq, max(1, nreq // 16)):
        k, x = flat[idx]
        direct = np.asarray(progs[k](jnp.asarray(x[None]))[0])
        bit_exact &= bool(np.array_equal(results[idx], direct))
    return {"req_s": nreq / dt, "nreq": nreq, "wall_s": dt,
            "recompiles": recompiles, "bit_exact": bit_exact,
            "p50_ms": m["latency_p50_ms"], "p99_ms": m["latency_p99_ms"],
            "scheduler": m["scheduler"], "banks": m["banks"]}


def virtual_scaling(reg, keys, banks=(1, 4), n_requests=240):
    """The canonical stream booked on n banks' worth of MVU slots: the
    cycle-domain makespan each fabric needs — the paper's scaling axis."""
    from repro.serving import SlotScheduler
    progs = {k: reg.program(k) for k in keys}
    bursts = stream(keys, n_requests)
    out = {}
    for nb in banks:
        sched = SlotScheduler(n_banks=nb)
        for key, xs in bursts:
            sched.admit(key, len(xs), program=progs[key])
        m = sched.metrics()
        out[nb] = {"virtual_cycles": m["virtual_cycles"],
                   "virtual_seconds": m["virtual_seconds"],
                   "req_per_vsec": (m["admitted_requests"]
                                    / m["virtual_seconds"]),
                   "bank_utilization": m["bank_utilization"]}
    return out


def sharded_batch(reg, key, n_banks=4, batch=256, iters=10):
    """One big batch: single device vs batch-sharded over the bank mesh."""
    import jax
    import jax.numpy as jnp
    from repro.distributed import program_parallel as pp
    prog = reg.program(key)
    rng = np.random.RandomState(2)
    x = rng.rand(batch, 8, 8, 8).astype(np.float32)
    ref = prog(jnp.asarray(x))
    jax.block_until_ready(ref)
    t0 = time.perf_counter()
    outs = [prog(jnp.asarray(x)) for _ in range(iters)]
    jax.block_until_ready(outs)
    dt1 = time.perf_counter() - t0
    sp = pp.ShardedProgram(prog, pp.bank_mesh(n_banks))
    got = sp(x)
    jax.block_until_ready(got)
    bit_exact = bool(np.array_equal(np.asarray(got), np.asarray(ref)))
    t0 = time.perf_counter()
    outs = [sp(x) for _ in range(iters)]
    jax.block_until_ready(outs)
    dtn = time.perf_counter() - t0
    return {"img_s_1": batch * iters / dt1, "img_s_n": batch * iters / dtn,
            "bit_exact": bit_exact, "batch": batch}


def pipelined(reg, key, n_stages=2, batch=32, iters=10):
    """Consecutive Program steps on consecutive banks (chip-to-chip
    streaming, the paper's pipelined mapping)."""
    import jax
    import jax.numpy as jnp
    from repro.distributed import program_parallel as pp
    prog = reg.program(key)
    rng = np.random.RandomState(3)
    x = rng.rand(batch, 8, 8, 8).astype(np.float32)
    ref = np.asarray(prog(jnp.asarray(x)))
    pl = pp.PipelinedProgram(prog, n_stages=n_stages)
    got = np.asarray(pl(x, n_microbatches=4))
    bit_exact = bool(np.array_equal(got, ref))
    t0 = time.perf_counter()
    outs = [pl(x, n_microbatches=4) for _ in range(iters)]
    jax.block_until_ready(outs)
    dt = time.perf_counter() - t0
    return {"img_s": batch * iters / dt, "bit_exact": bit_exact,
            "stages": [list(b) for b in pl.stage_bounds]}


def main():
    import jax
    n_dev = len(jax.devices())
    if n_dev < 4:
        # exit 0: the error JSON on stdout IS the report — the harness
        # parses it into a bench_distributed_error row
        print(json.dumps({"error": f"only {n_dev} devices"}))
        return 0
    reg, k_lo, k_hi = build_registry()
    keys = (k_lo, k_hi)
    wall1 = serve_wall(reg, keys, 1)
    wall4 = serve_wall(reg, keys, 4)
    virt = virtual_scaling(reg, keys)
    shard = sharded_batch(reg, k_lo)
    pipe = pipelined(reg, k_lo)
    print(json.dumps({
        "n_devices": n_dev,
        "cpu_count": os.cpu_count(),
        "wall": {"1": wall1, "4": wall4},
        "virtual": {str(k): v for k, v in virt.items()},
        "sharded": shard,
        "pipelined": pipe,
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
